//! Micro-benchmarks of the rust hot paths (perf-pass instrumentation):
//! voxelizer scatter, wire codec encode/decode, NMS, per-module XLA
//! execution, and the TCP frame protocol.
//!
//!   cargo bench --bench micro [-- keyword…]

use splitpoint::bench::{print_table, run_bench, BenchConfig, BenchResult};
use splitpoint::config::SystemConfig;
use splitpoint::coordinator::Engine;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::postprocess::nms::nms_bev;
use splitpoint::postprocess::Detection;
use splitpoint::tensor::codec::{Packet, Policy};
use splitpoint::util::rng::Rng;
use splitpoint::voxel::Voxelizer;
use splitpoint::Manifest;

fn want(filters: &[String], key: &str) -> bool {
    filters.is_empty() || filters.iter().any(|f| key.contains(f.as_str()))
}

fn main() -> anyhow::Result<()> {
    let filters: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with("--"))
        .collect();
    let cfg = BenchConfig::from_env();
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let mut results: Vec<BenchResult> = Vec::new();

    let scene = SceneGenerator::with_seed(1).generate();

    // ---- voxelizer
    if want(&filters, "voxelizer") {
        let vox = Voxelizer::from_config(&manifest.config);
        results.push(run_bench("voxelizer/scatter_20k_pts", cfg, || {
            let g = vox.voxelize(&scene.cloud);
            std::hint::black_box(g.in_range);
            None
        }));
    }

    // ---- codec
    if want(&filters, "codec") {
        let vox = Voxelizer::from_config(&manifest.config);
        let grids = vox.voxelize(&scene.cloud);
        let packet = Packet::new(vec![
            ("sum".into(), grids.sum.clone()),
            ("cnt".into(), grids.cnt.clone()),
        ]);
        for (name, policy) in [
            ("codec/encode_auto", Policy::Auto),
            ("codec/encode_dense", Policy::Dense),
            ("codec/encode_quant", Policy::AutoQuantized),
        ] {
            let p = packet.clone();
            results.push(run_bench(name, cfg, move || {
                std::hint::black_box(p.encode(policy).len());
                None
            }));
        }
        let bytes = packet.encode(Policy::Auto);
        results.push(run_bench("codec/decode_auto", cfg, move || {
            std::hint::black_box(Packet::decode(&bytes).unwrap().tensors.len());
            None
        }));
    }

    // ---- nms
    if want(&filters, "nms") {
        let mut rng = Rng::new(5);
        let mut dets: Vec<Detection> = (0..512)
            .map(|_| Detection {
                score: rng.f32(),
                boxx: [
                    rng.uniform(0.0, 46.0) as f32,
                    rng.uniform(-23.0, 23.0) as f32,
                    -1.0,
                    rng.uniform(1.0, 5.0) as f32,
                    rng.uniform(0.5, 2.5) as f32,
                    1.5,
                    rng.uniform(-3.1, 3.1) as f32,
                ],
                class: rng.below(3),
            })
            .collect();
        dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        results.push(run_bench("nms/512_boxes_keep96", cfg, move || {
            std::hint::black_box(nms_bev(&dets, 0.7, 96).len());
            None
        }));
    }

    // ---- per-module XLA execution + frame paths
    if want(&filters, "xla") || want(&filters, "frame") {
        let engine = Engine::new(&manifest, SystemConfig::paper())?;
        if want(&filters, "xla") {
            let (store, _) = engine.profile_frame(&scene.cloud)?;
            for node in engine.graph().nodes() {
                if node.kind != splitpoint::model::graph::NodeKind::Xla {
                    continue;
                }
                let inputs: Vec<_> = node
                    .inputs
                    .iter()
                    .map(|n| store[n].clone())
                    .collect();
                let name = format!("xla/{}", node.name);
                let rt = engine.runtime().clone();
                let module = node.name.clone();
                results.push(run_bench(&name, cfg, move || {
                    std::hint::black_box(rt.execute(&module, &inputs).unwrap().len());
                    None
                }));
            }
        }
        if want(&filters, "frame") {
            for split in ["vfe", "conv1", "edge_only"] {
                let sp = engine.graph().split_by_name(split)?;
                let name = format!("frame/wall_{split}");
                let e = &engine;
                let cloud = scene.cloud.clone();
                results.push(run_bench(&name, cfg, move || {
                    std::hint::black_box(e.run_frame(&cloud, sp).unwrap().detections.len());
                    None
                }));
            }
        }
    }

    print_table("micro benches (wall-clock host ms)", &results);
    Ok(())
}
