//! Micro-benchmarks of the rust hot paths (perf-pass instrumentation):
//! voxelizer scatter (pooled steady state), wire codec encode/decode, NMS,
//! per-module execution (scalar `@legacy` vs gather-GEMM), and the
//! whole-frame paths.
//!
//!   cargo bench --bench micro [-- keyword…] [-- --json] \
//!       [-- --threads N|max] [-- --simd auto|scalar|forced] [-- --out FILE]
//!
//! `--json` additionally writes `BENCH_micro.json` (or `--out FILE`) at
//! the repo root (per-bench mean/p50/p95 + throughput). The file keeps
//! the recorded `baseline` section across runs — the first full
//! single-threaded run seeds it — so the perf trajectory
//! (`speedup_vs_baseline`) is tracked in-tree; see docs/PERF.md.
//! `--threads` sizes the executor's kernel worker pool and `--simd` picks
//! the axpy dispatch (outputs are bit-identical at any combination; only
//! the clock moves). The JSON records the resolved dispatch in
//! `cpu_features` so the perf gate never compares baselines across
//! instruction sets, and the `runtime/*` hot paths run `@scalar` twins
//! (same engine, forced-scalar dispatch) yielding `speedup_vs_scalar` —
//! the SIMD win in isolation.

use std::collections::BTreeMap;
use std::sync::Arc;

use splitpoint::bench::{print_table, run_bench, BenchConfig, BenchResult};
use splitpoint::coordinator::SplitSession;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::pointcloud::ReplaySource;
use splitpoint::postprocess::nms::nms_bev;
use splitpoint::postprocess::Detection;
use splitpoint::runtime::reference::ReferenceModel;
use splitpoint::runtime::simd::{self, SimdMode};
use splitpoint::tensor::codec::{Packet, Policy, WirePrecision};
use splitpoint::util::cli::{parse_simd, parse_threads};
use splitpoint::util::json::{self, Value};
use splitpoint::util::rng::Rng;
use splitpoint::voxel::Voxelizer;
use splitpoint::{Manifest, Tensor};

fn want(filters: &[String], key: &str) -> bool {
    filters.is_empty() || filters.iter().any(|f| key.contains(f.as_str()))
}

fn main() -> anyhow::Result<()> {
    let mut json_out = false;
    let mut threads = 1usize;
    let mut simd_mode = SimdMode::Auto;
    let mut out_path = "BENCH_micro.json".to_string();
    let mut filters: Vec<String> = Vec::new();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        // accept both `--flag value` and `--flag=value`
        let (flag, inline) = match a.split_once('=') {
            Some((f, v)) => (f.to_string(), Some(v.to_string())),
            None => (a.clone(), None),
        };
        let mut value = |name: &str| -> anyhow::Result<String> {
            match inline.clone() {
                Some(v) => Ok(v),
                None => it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("{name} needs a value")),
            }
        };
        match flag.as_str() {
            "--json" => json_out = true,
            "--threads" => threads = parse_threads(Some(&value("--threads")?))?,
            "--simd" => simd_mode = parse_simd(Some(&value("--simd")?))?,
            "--out" => out_path = value("--out")?,
            s if s.starts_with("--") => {} // tolerate harness flags
            s => filters.push(s.to_string()),
        }
    }
    let cfg = BenchConfig::from_env();
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let mut results: Vec<BenchResult> = Vec::new();

    let scene = SceneGenerator::with_seed(1).generate();

    // ---- voxelizer: pooled steady state (scatter + sparse clear), plus
    // the pre-refactor behaviour measured from HEAD (`@legacy`: no pool —
    // every frame allocates and zero-fills the dense grids)
    if want(&filters, "voxelizer") {
        let vox = Voxelizer::from_config(&manifest.config);
        results.push(run_bench("voxelizer/scatter_20k_pts", cfg, || {
            let g = vox.voxelize(&scene.cloud);
            std::hint::black_box(g.in_range);
            vox.recycle(g);
            None
        }));
        let cold = Voxelizer::from_config(&manifest.config);
        results.push(run_bench("voxelizer/scatter_20k_pts@legacy", cfg, || {
            let g = cold.voxelize(&scene.cloud);
            std::hint::black_box(g.in_range);
            // grids dropped, never recycled: fresh 4.25 MB alloc + zero
            // per frame, like the pre-refactor scatter
            None
        }));
    }

    // ---- codec: the VFE-split live set at KITTI-like occupancy
    if want(&filters, "codec") {
        let vox = Voxelizer::from_config(&manifest.config);
        let grids = vox.voxelize(&scene.cloud);
        let packet = Packet::from_shared(vec![
            ("sum".into(), grids.sum.clone()),
            ("cnt".into(), grids.cnt.clone()),
        ]);
        for (name, policy) in [
            ("codec/encode_sparse", Policy::Auto),
            ("codec/encode_dense", Policy::Dense),
            ("codec/encode_quant", Policy::AutoQuantized),
        ] {
            let p = packet.clone();
            results.push(run_bench(name, cfg, move || {
                std::hint::black_box(p.encode(policy).len());
                None
            }));
        }
        // steady-state wire path: encode into a reused, exactly-sized buffer
        {
            let p = packet.clone();
            let mut buf = Vec::new();
            results.push(run_bench("codec/encode_sparse_reused_buf", cfg, move || {
                p.encode_into(Policy::Auto, &mut buf);
                std::hint::black_box(buf.len());
                None
            }));
        }
        // pre-refactor behaviour measured from HEAD: the old frame path
        // deep-cloned every tensor into the packet and had no cached site
        // index, so each encode rescanned the dense grids (cold caches)
        {
            let (sum, cnt) = (grids.sum.clone(), grids.cnt.clone());
            // from_vec strips the cached site index: every iteration pays
            // the deep clone + the scan-then-emit double pass, like the
            // string-keyed engine did
            let cold = |t: &Arc<Tensor>| Tensor::from_vec(t.shape(), t.data().to_vec()).unwrap();
            results.push(run_bench("codec/encode_sparse@legacy", cfg, move || {
                let p = Packet::new(vec![
                    ("sum".into(), cold(&sum)),
                    ("cnt".into(), cold(&cnt)),
                ]);
                std::hint::black_box(p.encode(Policy::Auto).len());
                None
            }));
        }
        // the delta/varint run-length site index (wire v2) vs the raw-u32
        // v1 framing re-created in-run as its `@legacy` twin; the byte
        // counts are printed once since the win is size as much as time
        {
            let (p, p_legacy) = (packet.clone(), packet.clone());
            let mut buf = Vec::new();
            results.push(run_bench("codec/encode_sparse_delta", cfg, move || {
                p.encode_into(Policy::Auto, &mut buf);
                std::hint::black_box(buf.len());
                None
            }));
            let mut buf1 = Vec::new();
            results.push(run_bench("codec/encode_sparse_delta@legacy", cfg, move || {
                p_legacy
                    .encode_versioned_into(Policy::Auto, 1, &mut buf1)
                    .unwrap();
                std::hint::black_box(buf1.len());
                None
            }));
            let mut v1 = Vec::new();
            packet.encode_versioned_into(Policy::Auto, 1, &mut v1)?;
            let v2 = packet.encode(Policy::Auto);
            eprintln!(
                "[micro] sparse VFE live set: v2 delta index {} B vs v1 raw index {} B ({:.1}% smaller)",
                v2.len(),
                v1.len(),
                (1.0 - v2.len() as f64 / v1.len() as f64) * 100.0
            );
        }
        // wire v3 quantized payloads (f16 halves, int8 quarters the value
        // bytes) vs the exact f32/v2 encode of the same packet as the
        // `@legacy` twin — speedup_vs_legacy reads as the quantize cost
        // (or win: fewer bytes to write) at equal input
        for (name, precision) in [
            ("codec/encode_sparse_v3_f16", WirePrecision::F16),
            ("codec/encode_sparse_v3_int8", WirePrecision::Int8),
        ] {
            {
                let p = packet.clone();
                let mut buf = Vec::new();
                results.push(run_bench(name, cfg, move || {
                    p.encode_wire_into(Policy::Auto, precision, &mut buf);
                    std::hint::black_box(buf.len());
                    None
                }));
            }
            {
                let p = packet.clone();
                let mut buf = Vec::new();
                results.push(run_bench(&format!("{name}@legacy"), cfg, move || {
                    p.encode_wire_into(Policy::Auto, WirePrecision::F32, &mut buf);
                    std::hint::black_box(buf.len());
                    None
                }));
            }
        }
        eprintln!(
            "[micro] sparse VFE live set: f32 {} B, f16 {} B, int8 {} B",
            packet.encoded_size_wire(Policy::Auto, WirePrecision::F32),
            packet.encoded_size_wire(Policy::Auto, WirePrecision::F16),
            packet.encoded_size_wire(Policy::Auto, WirePrecision::Int8),
        );
        let bytes = packet.encode(Policy::Auto);
        results.push(run_bench("codec/decode_sparse", cfg, move || {
            std::hint::black_box(Packet::decode(&bytes).unwrap().tensors.len());
            None
        }));
    }

    // ---- nms
    if want(&filters, "nms") {
        let mut rng = Rng::new(5);
        let mut dets: Vec<Detection> = (0..512)
            .map(|_| Detection {
                score: rng.f32(),
                boxx: [
                    rng.uniform(0.0, 46.0) as f32,
                    rng.uniform(-23.0, 23.0) as f32,
                    -1.0,
                    rng.uniform(1.0, 5.0) as f32,
                    rng.uniform(0.5, 2.5) as f32,
                    1.5,
                    rng.uniform(-3.1, 3.1) as f32,
                ],
                class: rng.below(3),
            })
            .collect();
        dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        results.push(run_bench("nms/512_boxes_keep96", cfg, move || {
            std::hint::black_box(nms_bev(&dets, 0.7, 96).len());
            None
        }));
    }

    // ---- gather-GEMM kernel stages vs their scalar `@legacy` twins (the
    // perf-gate's canonical before/after pair; targets in docs/PERF.md:
    // ≥1.5x at --threads max, ≥1.15x single-threaded from layout/blocking)
    // and their `@scalar` twins (same gather-GEMM engine, forced-scalar
    // axpy dispatch; target ≥1.5x SIMD-vs-scalar at threads=1 on AVX2)
    if want(&filters, "runtime") {
        let engine = SplitSession::builder()
            .threads(threads)
            .simd(simd_mode)
            .build_engine()?;
        let scalar_engine = SplitSession::builder()
            .threads(threads)
            .simd(SimdMode::Scalar)
            .build_engine()?;
        let (store, _) = engine.profile_frame(&scene.cloud)?;
        let legacy = ReferenceModel::new(&manifest)?;
        for module in ["conv1", "bev_head"] {
            let node = engine
                .graph()
                .nodes()
                .iter()
                .find(|n| n.name == module)
                .expect("manifest module");
            let inputs: Vec<Arc<Tensor>> = node
                .input_ids()
                .iter()
                .map(|&id| store.get(id).expect("profiled input").clone())
                .collect();
            let bench_name = if module == "bev_head" {
                "runtime/bev_head".to_string()
            } else {
                "runtime/conv_stage".to_string()
            };
            {
                let rt = engine.runtime().clone();
                let module = module.to_string();
                let inputs = inputs.clone();
                results.push(run_bench(&bench_name, cfg, move || {
                    std::hint::black_box(rt.execute(&module, &inputs).unwrap().len());
                    None
                }));
            }
            {
                let rt = scalar_engine.runtime().clone();
                let module = module.to_string();
                let inputs = inputs.clone();
                results.push(run_bench(&format!("{bench_name}@scalar"), cfg, move || {
                    std::hint::black_box(rt.execute(&module, &inputs).unwrap().len());
                    None
                }));
            }
            let idx = legacy.module_index(module).expect("legacy module");
            let lm = &legacy;
            results.push(run_bench(&format!("{bench_name}@legacy"), cfg, move || {
                std::hint::black_box(lm.execute_legacy(idx, &inputs).unwrap().len());
                None
            }));
        }
    }

    // ---- per-module execution + whole-frame paths
    if want(&filters, "xla") || want(&filters, "run_frame") {
        let engine = SplitSession::builder().threads(threads).simd(simd_mode).build_engine()?;
        if want(&filters, "xla") {
            let (store, _) = engine.profile_frame(&scene.cloud)?;
            for node in engine.graph().nodes() {
                if node.kind != splitpoint::model::graph::NodeKind::Xla {
                    continue;
                }
                let inputs: Vec<Arc<Tensor>> = node
                    .input_ids()
                    .iter()
                    .map(|&id| store.get(id).expect("profiled input").clone())
                    .collect();
                let name = format!("xla/{}", node.name);
                let rt = engine.runtime().clone();
                let module = node.name.clone();
                results.push(run_bench(&name, cfg, move || {
                    std::hint::black_box(rt.execute(&module, &inputs).unwrap().len());
                    None
                }));
            }
            // the in-flight handle API: two independent executions of the
            // same module overlapped on worker threads (vs the blocking
            // xla/vfe number above, back to back)
            {
                let vfe = engine
                    .graph()
                    .nodes()
                    .iter()
                    .find(|n| n.name == "vfe")
                    .expect("vfe node");
                let inputs: Vec<Arc<Tensor>> = vfe
                    .input_ids()
                    .iter()
                    .map(|&id| store.get(id).expect("profiled input").clone())
                    .collect();
                let rt = engine.runtime().clone();
                results.push(run_bench("xla/vfe_inflight_pair", cfg, move || {
                    let a = splitpoint::runtime::XlaRuntime::submit(&rt, "vfe", inputs.clone())
                        .unwrap();
                    let b = splitpoint::runtime::XlaRuntime::submit(&rt, "vfe", inputs.clone())
                        .unwrap();
                    std::hint::black_box(a.wait().unwrap().len() + b.wait().unwrap().len());
                    None
                }));
            }
        }
        if want(&filters, "run_frame") {
            for split in ["vfe", "conv1", "edge_only"] {
                let sp = engine.graph().split_by_name(split)?;
                let name = format!("run_frame/{split}");
                let e = &engine;
                let cloud = scene.cloud.clone();
                results.push(run_bench(&name, cfg, move || {
                    std::hint::black_box(e.run_frame(&cloud, sp).unwrap().detections.len());
                    None
                }));
            }
        }
    }

    // ---- pipelined multi-frame execution: 16-frame streams through the
    // staged scheduler. The serial run_frame loop *is* the pre-pipeline
    // behaviour, measured from HEAD as the `@legacy` twin, so
    // `speedup_vs_legacy["pipeline/stream_16_frames"]` reads directly as
    // the pipelined-over-serial throughput ratio (target ≥1.2x at depth 2;
    // see docs/PERF.md).
    if want(&filters, "pipeline") {
        use splitpoint::coordinator::pipeline::{self, PipelineConfig};
        // split the worker budget with the two tail stages so kernel and
        // stage parallelism compose (the builder does the same arithmetic)
        let engine = SplitSession::builder()
            .threads(threads)
            .simd(simd_mode)
            .pipeline_depth(2)
            .tail_workers(2)
            .build_engine()?;
        let sp = engine.graph().split_after("vfe")?;
        let clouds: Vec<_> = (0..16)
            .map(|i| SceneGenerator::with_seed(100 + i as u64).generate().cloud)
            .collect();
        {
            // the serial twin gets the FULL thread budget (no tail workers
            // to share with) so speedup_vs_legacy isolates stage overlap
            // instead of comparing against a kernel-handicapped baseline
            let serial = SplitSession::builder().threads(threads).simd(simd_mode).build_engine()?;
            let cl = clouds.clone();
            results.push(run_bench("pipeline/stream_16_frames@legacy", cfg, move || {
                for c in &cl {
                    std::hint::black_box(serial.run_frame(c, sp).unwrap().detections.len());
                }
                None
            }));
        }
        for (name, depth) in [
            ("pipeline/stream_16_frames", 2),
            ("pipeline/stream_16_frames@depth4", 4),
        ] {
            let e = engine.clone();
            let cl = clouds.clone();
            results.push(run_bench(name, cfg, move || {
                let (res, _report) = pipeline::run_stream(
                    e.clone(),
                    sp,
                    &cl,
                    PipelineConfig {
                        depth,
                        tail_workers: 2,
                    },
                )
                .unwrap();
                std::hint::black_box(res.len());
                None
            }));
        }
    }

    // ---- the SplitSession facade end-to-end: the same 16-frame stream
    // assembled through the builder (replay source + in-process transport,
    // depth-2 pipeline over a shared engine). Tracks the facade's overhead
    // against pipeline/stream_16_frames — the session is a thin shell, so
    // the two should stay within noise of each other.
    if want(&filters, "session") {
        let engine = SplitSession::builder()
            .threads(threads)
            .simd(simd_mode)
            .pipeline_depth(2)
            .tail_workers(2)
            .build_engine()?;
        let clouds: Vec<_> = (0..16)
            .map(|i| SceneGenerator::with_seed(100 + i as u64).generate().cloud)
            .collect();
        results.push(run_bench("session/stream_16_frames", cfg, move || {
            let mut session = SplitSession::builder()
                .engine(engine.clone())
                .pipeline_depth(2)
                .tail_workers(2)
                .source(Box::new(ReplaySource::from_clouds(clouds.clone())))
                .build()
                .unwrap();
            let (frames, _report) = session.run().unwrap();
            std::hint::black_box(frames.len());
            None
        }));
    }

    print_table("micro benches (wall-clock host ms)", &results);
    if json_out {
        let dispatch = simd::resolve(simd_mode)?;
        write_json(&results, cfg, filters.is_empty(), threads, dispatch, &out_path)?;
    }
    Ok(())
}

/// Write the bench JSON: current numbers, the tracked baseline, and
/// per-bench speedups. The baseline is only seeded/extended by *full*
/// (unfiltered) runs so a keyword-filtered run can never pin a partial
/// baseline; `@legacy` benches re-measure the pre-refactor behaviour from
/// HEAD, yielding a before/after pair in every run.
fn write_json(
    results: &[BenchResult],
    cfg: BenchConfig,
    full_run: bool,
    threads: usize,
    dispatch: simd::SimdLevel,
    out_path: &str,
) -> anyhow::Result<()> {
    let mut current: BTreeMap<String, Value> = BTreeMap::new();
    for r in results {
        let mean = r.stats.mean();
        let mut e = BTreeMap::new();
        e.insert("mean_ms".to_string(), Value::num(mean));
        e.insert("p50_ms".to_string(), Value::num(r.stats.p50()));
        e.insert("p95_ms".to_string(), Value::num(r.stats.p95()));
        e.insert(
            "throughput_per_s".to_string(),
            Value::num(if mean > 0.0 { 1000.0 / mean } else { 0.0 }),
        );
        current.insert(r.name.clone(), Value::Obj(e));
    }

    let existing = std::fs::read_to_string(out_path)
        .ok()
        .and_then(|t| json::parse(&t).ok());
    let mut baseline: BTreeMap<String, Value> = existing
        .as_ref()
        .and_then(|v| v.get("baseline"))
        .and_then(Value::as_obj)
        .cloned()
        .unwrap_or_default();
    if full_run {
        // first full run seeds the baseline; later full runs only add
        // benches the baseline has never seen
        for (k, v) in &current {
            baseline.entry(k.clone()).or_insert_with(|| v.clone());
        }
    }

    let mean_of = |v: &Value| v.get("mean_ms").and_then(Value::as_f64);
    let mut vs_baseline: BTreeMap<String, Value> = BTreeMap::new();
    let mut vs_legacy: BTreeMap<String, Value> = BTreeMap::new();
    let mut vs_scalar: BTreeMap<String, Value> = BTreeMap::new();
    for (k, cur) in &current {
        let cm = mean_of(cur);
        if let (Some(bm), Some(cm)) = (baseline.get(k).and_then(&mean_of), cm) {
            if cm > 0.0 {
                vs_baseline.insert(k.clone(), Value::num(bm / cm));
            }
        }
        // "name" vs "name@legacy" measured in the same run
        if let (Some(lm), Some(cm)) =
            (current.get(&format!("{k}@legacy")).and_then(&mean_of), cm)
        {
            if cm > 0.0 {
                vs_legacy.insert(k.clone(), Value::num(lm / cm));
            }
        }
        // "name" vs "name@scalar" — the SIMD win in isolation (same
        // gather-GEMM engine, forced-scalar axpy dispatch)
        if let (Some(sm), Some(cm)) =
            (current.get(&format!("{k}@scalar")).and_then(&mean_of), cm)
        {
            if cm > 0.0 {
                vs_scalar.insert(k.clone(), Value::num(sm / cm));
            }
        }
    }

    let out = Value::Obj(BTreeMap::from([
        (
            "schema".to_string(),
            Value::str("splitpoint-micro-bench/v1"),
        ),
        ("status".to_string(), Value::str("measured")),
        ("iters".to_string(), Value::num(cfg.iters as f64)),
        ("warmup_iters".to_string(), Value::num(cfg.warmup_iters as f64)),
        ("threads".to_string(), Value::num(threads as f64)),
        (
            "cpu_features".to_string(),
            Value::Obj(BTreeMap::from([
                ("arch".to_string(), Value::str(std::env::consts::ARCH)),
                ("dispatch".to_string(), Value::str(dispatch.name())),
                ("detected".to_string(), Value::str(simd::detect().name())),
            ])),
        ),
        ("baseline".to_string(), Value::Obj(baseline)),
        ("current".to_string(), Value::Obj(current)),
        ("speedup_vs_baseline".to_string(), Value::Obj(vs_baseline)),
        ("speedup_vs_legacy".to_string(), Value::Obj(vs_legacy)),
        ("speedup_vs_scalar".to_string(), Value::Obj(vs_scalar)),
    ]));
    std::fs::write(out_path, out.pretty())?;
    eprintln!("[micro] wrote {out_path}");
    Ok(())
}
