//! Integration tests over the real AOT artifacts (require `make artifacts`).
//!
//! The centrepiece is the split-computing correctness theorem: for every
//! split point, the detections must equal the edge-only run — splitting is
//! an implementation detail of *where* compute happens, never of *what* is
//! computed.

use std::path::PathBuf;
use std::sync::Arc;

use splitpoint::config::SystemConfig;
use splitpoint::coordinator::adaptive;
use splitpoint::coordinator::remote::{EdgeClient, Server};
use splitpoint::coordinator::Engine;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::postprocess::Detection;
use splitpoint::tensor::codec::Policy;
use splitpoint::Manifest;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn load_manifest() -> Manifest {
    Manifest::load(&artifacts_dir()).expect("run `make artifacts` before cargo test")
}

/// One shared engine for the whole test binary (PJRT compile is expensive).
fn engine() -> &'static Engine {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Engine> = OnceLock::new();
    ENGINE.get_or_init(|| {
        let manifest = load_manifest();
        Engine::new(&manifest, SystemConfig::paper()).expect("engine")
    })
}

fn dets_equal(a: &[Detection], b: &[Detection], tol: f32) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.class == y.class
                && (x.score - y.score).abs() <= tol
                && x.boxx
                    .iter()
                    .zip(&y.boxx)
                    .all(|(p, q)| (p - q).abs() <= tol * 10.0)
        })
}

#[test]
fn split_equals_unsplit_at_every_point() {
    let e = engine();
    let scene = SceneGenerator::with_seed(42).generate();
    let baseline = e
        .run_frame(&scene.cloud, e.graph().split_edge_only())
        .expect("edge-only run");
    assert!(!baseline.detections.is_empty(), "baseline found nothing");
    for sp in e.graph().all_splits() {
        let r = e.run_frame(&scene.cloud, sp).expect("split run");
        assert!(
            dets_equal(&r.detections, &baseline.detections, 1e-4),
            "split '{}' diverged from edge-only ({} vs {} dets)",
            e.graph().split_label(sp),
            r.detections.len(),
            baseline.detections.len()
        );
    }
}

#[test]
fn timing_breakdown_is_consistent() {
    let e = engine();
    let scene = SceneGenerator::with_seed(7).generate();
    for sp in e.graph().all_splits() {
        let r = e.run_frame(&scene.cloud, sp).unwrap();
        let t = &r.timing;
        // inference covers edge time
        assert!(t.inference_time >= t.edge_time, "{}", t.split_label);
        // every node ran exactly once
        assert_eq!(t.node_times.len(), e.graph().len());
        // edge-only has no wire traffic; others have uplink
        if sp.head_len == e.graph().len() {
            assert_eq!(t.uplink_bytes, 0);
            assert_eq!(t.uplink_time.nanos, 0);
        } else {
            assert!(t.uplink_bytes > 0, "{}", t.split_label);
            assert!(t.uplink_time.nanos > 0);
            assert!(t.downlink_bytes > 0);
        }
    }
}

#[test]
fn transfer_sizes_reproduce_fig8_ordering() {
    // the paper's Fig 8 mechanism: VFE wire < raw cloud < conv1 < conv2
    let e = engine();
    let scene = SceneGenerator::with_seed(11).generate();
    let raw = scene.cloud.size_bytes();
    let bytes = |name: &str| {
        e.run_frame(&scene.cloud, e.graph().split_after(name).unwrap())
            .unwrap()
            .timing
            .uplink_bytes
    };
    let vfe = bytes("vfe");
    let conv1 = bytes("conv1");
    let conv2 = bytes("conv2");
    assert!(vfe < raw, "vfe {vfe} !< raw {raw}");
    assert!(raw < conv1, "raw {raw} !< conv1 {conv1}");
    assert!(conv1 < conv2, "conv1 {conv1} !< conv2 {conv2}");
}

#[test]
fn quantized_codec_shrinks_wire_and_preserves_detections() {
    let manifest = load_manifest();
    let e = engine();
    let mut cfg = SystemConfig::paper();
    cfg.codec = Policy::AutoQuantized;
    let eq = Engine::with_runtime(&manifest, cfg, e.runtime().clone()).unwrap();

    let scene = SceneGenerator::with_seed(13).generate();
    let sp = e.graph().split_after("conv1").unwrap();
    let exact = e.run_frame(&scene.cloud, sp).unwrap();
    let quant = eq.run_frame(&scene.cloud, sp).unwrap();
    assert!(
        quant.timing.uplink_bytes < exact.timing.uplink_bytes * 2 / 3,
        "int8 should shrink the wire: {} vs {}",
        quant.timing.uplink_bytes,
        exact.timing.uplink_bytes
    );
    // lossy but close: counts may differ by threshold-straddling slots,
    // and near-tied ranks may swap — require that most exact detections
    // have a same-class, high-IoU counterpart in the quantized set
    let (nq, ne) = (quant.detections.len(), exact.detections.len());
    assert!(
        (nq as i64 - ne as i64).unsigned_abs() as usize <= ne / 5 + 2,
        "detection count drifted too far: {nq} vs {ne}"
    );
    let gts: Vec<_> = exact
        .detections
        .iter()
        .map(|d| splitpoint::postprocess::eval::GroundTruth {
            boxx: d.boxx,
            class: d.class,
        })
        .collect();
    let m = splitpoint::postprocess::eval::match_frame(&quant.detections, &gts, 0.7, false);
    assert!(
        m.matches.len() * 10 >= ne * 7,
        "only {}/{} exact detections survived quantization",
        m.matches.len(),
        ne
    );
}

#[test]
fn adaptive_estimates_match_measurements() {
    let e = engine();
    let scene = SceneGenerator::with_seed(17).generate();
    let estimates = adaptive::estimate_splits(e, &scene.cloud).unwrap();
    for est in estimates {
        let r = e.run_frame(&scene.cloud, est.split).unwrap();
        // the additive cost model matches the engine up to host-timing
        // noise (XLA executions vary run to run) and the encode/decode
        // cost the analytic model omits
        let measured = r.timing.inference_time.as_millis_f64();
        let predicted = est.inference_time.as_millis_f64();
        let rel = (measured - predicted).abs() / measured.max(1.0);
        assert!(
            rel < 0.5,
            "split '{}': predicted {predicted:.1} ms, measured {measured:.1} ms",
            est.label
        );
        assert_eq!(est.uplink_bytes, r.timing.uplink_bytes, "{}", est.label);
    }
}

#[test]
fn tcp_roundtrip_matches_local() {
    let manifest = load_manifest();
    let e = engine();
    let shared = Arc::new(
        Engine::with_runtime(&manifest, SystemConfig::paper(), e.runtime().clone()).unwrap(),
    );
    let server = Server::spawn("127.0.0.1:0", shared.clone()).unwrap();
    let addr = server.addr();

    let scene = SceneGenerator::with_seed(23).generate();
    let sp = shared.graph().split_after("vfe").unwrap();
    let local = shared.run_frame(&scene.cloud, sp).unwrap();

    let mut client = EdgeClient::connect(addr, shared.clone()).unwrap();
    let (dets, timing) = client.run_frame(&scene.cloud, sp).unwrap();
    assert!(dets_equal(&dets, &local.detections, 1e-4));
    assert!(timing.uplink_bytes > 0);
    assert!(timing.inference_time.nanos > 0);
    client.shutdown().unwrap();
    server.shutdown().unwrap();
}

#[test]
fn tcp_serves_multiple_clients_and_splits() {
    let manifest = load_manifest();
    let e = engine();
    let shared = Arc::new(
        Engine::with_runtime(&manifest, SystemConfig::paper(), e.runtime().clone()).unwrap(),
    );
    let server = Server::spawn("127.0.0.1:0", shared.clone()).unwrap();
    let addr = server.addr();

    let mut handles = Vec::new();
    for (i, split) in ["vfe", "conv1"].iter().enumerate() {
        let shared = shared.clone();
        let split = split.to_string();
        handles.push(std::thread::spawn(move || {
            let sp = shared.graph().split_after(&split).unwrap();
            let scene = SceneGenerator::with_seed(100 + i as u64).generate();
            let mut client = EdgeClient::connect(addr, shared.clone()).unwrap();
            for _ in 0..2 {
                let (dets, _) = client.run_frame(&scene.cloud, sp).unwrap();
                assert!(!dets.is_empty());
            }
            client.shutdown().unwrap();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown().unwrap();
}

#[test]
fn empty_cloud_runs_cleanly() {
    let e = engine();
    let empty = splitpoint::pointcloud::PointCloud::default();
    for name in ["vfe", "conv2"] {
        let r = e
            .run_frame(&empty, e.graph().split_after(name).unwrap())
            .unwrap();
        // no points -> zero grids -> the pipeline still produces K slots,
        // all padding or low-score; no crash is the contract
        assert!(r.timing.inference_time.nanos > 0);
    }
}

#[test]
fn runtime_rejects_bad_shapes() {
    let e = engine();
    let bad = Arc::new(splitpoint::Tensor::zeros(&[2, 2]));
    assert!(e.runtime().execute("vfe", &[bad.clone(), bad]).is_err());
    assert!(e.runtime().execute("nonexistent", &[]).is_err());
}

#[test]
fn voxel_scratch_pool_recycles_after_frames() {
    // the engine hands points_sum/points_cnt back to the voxelizer pool at
    // frame teardown unless a packet still shares them; either way the
    // next frame's results are identical (covered by
    // split_equals_unsplit_at_every_point running the same cloud through
    // many splits, which reuses pooled grids after the first frame)
    let e = engine();
    let scene = SceneGenerator::with_seed(31).generate();
    let sp = e.graph().split_after("vfe").unwrap();
    let a = e.run_frame(&scene.cloud, sp).unwrap();
    let b = e.run_frame(&scene.cloud, sp).unwrap();
    assert!(dets_equal(&a.detections, &b.detections, 0.0), "frames must be deterministic");
    assert_eq!(a.timing.uplink_bytes, b.timing.uplink_bytes);
}
