//! Telemetry-plane invariants: the Prometheus text rendering is pinned
//! byte-for-byte (metric names and formatting are a compatibility
//! surface — dashboards and the CI soak gate grep for them), the HTTP
//! exporter serves exactly what `render()` produces, and a session run
//! populates the process-wide registry without perturbing detections.

use std::path::PathBuf;
use std::sync::Arc;

use splitpoint::coordinator::fault::LinkHealth;
use splitpoint::coordinator::session::SessionFrame;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::pointcloud::{PointCloud, ReplaySource};
use splitpoint::telemetry::sla::{parse_specs, SlaEvaluator, SlaKind};
use splitpoint::telemetry::{MetricsServer, Registry};
use splitpoint::SplitSession;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn clouds(seed0: u64, n: usize) -> Vec<PointCloud> {
    (0..n)
        .map(|i| SceneGenerator::with_seed(seed0 + i as u64).generate().cloud)
        .collect()
}

/// Seed a registry with one of every instrument shape, deterministically.
fn seeded_registry() -> Registry {
    let reg = Registry::new();
    reg.counter("sp_test_frames_total", "Frames completed.", &[]).add(3);
    reg.counter("sp_test_bytes_total", "Bytes shipped.", &[("direction", "up")])
        .add(1024);
    reg.counter("sp_test_bytes_total", "Bytes shipped.", &[("direction", "down")])
        .add(512);
    reg.gauge("sp_test_rtt_seconds", "Smoothed RTT.", &[]).set(0.25);
    let h = reg.histogram(
        "sp_test_latency_seconds",
        "Stage latency.",
        &[("stage", "tail")],
        &[0.01, 0.1, 1.0],
    );
    h.observe(0.005);
    h.observe(0.05);
    h.observe(0.05);
    h.observe(2.0);
    reg
}

/// The pinned text-format rendering of [`seeded_registry`]: families and
/// label sets sorted, cumulative `le` buckets with `+Inf`, `_sum` and
/// `_count`. Any change here is a breaking change to the scrape surface
/// and needs a deprecation note in `docs/METRICS.md`.
const GOLDEN: &str = "\
# HELP sp_test_bytes_total Bytes shipped.
# TYPE sp_test_bytes_total counter
sp_test_bytes_total{direction=\"down\"} 512
sp_test_bytes_total{direction=\"up\"} 1024
# HELP sp_test_frames_total Frames completed.
# TYPE sp_test_frames_total counter
sp_test_frames_total 3
# HELP sp_test_latency_seconds Stage latency.
# TYPE sp_test_latency_seconds histogram
sp_test_latency_seconds_bucket{stage=\"tail\",le=\"0.01\"} 1
sp_test_latency_seconds_bucket{stage=\"tail\",le=\"0.1\"} 3
sp_test_latency_seconds_bucket{stage=\"tail\",le=\"1\"} 3
sp_test_latency_seconds_bucket{stage=\"tail\",le=\"+Inf\"} 4
sp_test_latency_seconds_sum{stage=\"tail\"} 2.105
sp_test_latency_seconds_count{stage=\"tail\"} 4
# HELP sp_test_rtt_seconds Smoothed RTT.
# TYPE sp_test_rtt_seconds gauge
sp_test_rtt_seconds 0.25
";

/// Golden test: `Registry::render` is deterministic and pinned.
#[test]
fn render_matches_golden_text() {
    assert_eq!(seeded_registry().render(), GOLDEN);
    // a second render of the same state is byte-identical
    let reg = seeded_registry();
    assert_eq!(reg.render(), reg.render());
}

/// The HTTP exporter serves exactly the registry rendering — the scrape
/// body is the golden text, unmodified.
#[test]
fn http_scrape_returns_exact_rendering() {
    let reg = Arc::new(seeded_registry());
    let mut srv = MetricsServer::spawn("127.0.0.1:0", reg).expect("spawn metrics endpoint");
    let body = splitpoint::telemetry::scrape(srv.addr()).expect("scrape");
    assert_eq!(body, GOLDEN);
    srv.shutdown();
}

/// Every rendered line is promtool-parseable: a comment, or
/// `name{labels} value` with a bare-token value (the shape the CI soak
/// gate enforces with a regex).
#[test]
fn rendered_lines_are_parseable() {
    for line in seeded_registry().render().lines() {
        if line.starts_with('#') {
            continue;
        }
        let (series, value) = line.rsplit_once(' ').expect("metric line has a value");
        assert!(!series.is_empty() && value.parse::<f64>().is_ok(), "bad line: {line}");
        let name = series.split('{').next().unwrap();
        assert!(
            name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
            "bad metric name in: {line}"
        );
    }
}

/// SLA evaluator against a scripted window: breach detection, the
/// exported `sp_sla_*` families, and the one-line verdict `run --report`
/// prints.
#[test]
fn sla_evaluator_exports_breach_state() {
    let reg = Registry::new();
    let specs = parse_specs("latency-bound=0.1,bytes-bound=1000000").expect("parse");
    let mut sla = SlaEvaluator::new(specs, &reg);

    sla.observe_frame(0.05, 500_000, 0.02);
    let v = sla.evaluate(&LinkHealth::default());
    assert!(!v.any_breached());
    assert!(v.line().contains("latency-bound ok"), "got: {}", v.line());

    sla.observe_frame(0.5, 2_000_000, 0.02);
    let v = sla.evaluate(&LinkHealth::default());
    assert!(v.any_breached());
    assert_eq!(v.statuses[0].kind, SlaKind::LatencyBound);
    assert!(v.statuses.iter().all(|s| s.breached));
    assert!(v.line().contains("BREACHED"), "got: {}", v.line());

    let text = reg.render();
    assert!(text.contains("sp_sla_threshold{objective=\"latency-bound\"} 0.1"), "{text}");
    assert!(text.contains("sp_sla_breached{objective=\"latency-bound\"} 1"), "{text}");
    assert!(text.contains("sp_sla_breached{objective=\"bytes-bound\"} 1"), "{text}");
    assert!(text.contains("sp_sla_breaches_total{objective=\"bytes-bound\"} 1"), "{text}");
}

/// End-to-end: a pipelined session with declared SLA objectives streams
/// normally (telemetry must never perturb output), lands a verdict in the
/// report, and populates the process-wide registry that
/// `SessionReport::prometheus` renders.
#[test]
fn session_run_populates_global_registry_and_sla_verdict() {
    let stream = clouds(40_000, 3);
    let mut session = SplitSession::builder()
        .artifacts(artifacts_dir())
        .source(Box::new(ReplaySource::from_clouds(stream.clone())))
        .pipeline_depth(2)
        // bytes-bound=1 is unmeetable (every frame ships more than one
        // byte); latency-bound=1000 is unmissable — a deterministic
        // mixed verdict without depending on wall-clock speed
        .sla_specs(parse_specs("latency-bound=1000,bytes-bound=1").expect("parse"))
        .build()
        .expect("run `make artifacts` before cargo test");
    let mut delivered = 0usize;
    let report = session
        .run_with(|_f: SessionFrame| {
            delivered += 1;
        })
        .unwrap();
    assert_eq!(delivered, stream.len());

    let sla = report.sla.as_ref().expect("objectives were declared");
    assert!(sla.any_breached(), "bytes-bound=1 must breach");
    let breached: Vec<SlaKind> = sla
        .statuses
        .iter()
        .filter(|s| s.breached)
        .map(|s| s.kind)
        .collect();
    assert_eq!(breached, [SlaKind::BytesBound], "latency-bound=1000 must hold");

    let text = report.prometheus();
    for family in [
        "sp_session_frames_total",
        "sp_session_uplink_bytes_total",
        "sp_session_uplink_v1_bytes_total",
        "sp_session_uplink_v3_bytes_total",
        "sp_pipeline_frames_total",
        "sp_stage_latency_seconds_bucket",
        "sp_queue_depth_bucket",
        "sp_runtime_threads",
        "sp_sla_breached{objective=\"bytes-bound\"} 1",
    ] {
        assert!(text.contains(family), "missing '{family}' in:\n{text}");
    }
}
