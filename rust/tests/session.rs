//! `SplitSession` facade invariants (require `make artifacts`).
//!
//! The contract under test: a session is an *assembly* of source ×
//! transport × policy, never a semantic change. Whatever the policy
//! schedule, pipeline depth, or transport, per-frame detections must be
//! byte-identical to `Engine::run_frame` at the split the session chose
//! for that frame — no cross-frame state leakage when the split flips
//! mid-stream.

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use splitpoint::config::SystemConfig;
use splitpoint::coordinator::adaptive::Objective;
use splitpoint::coordinator::pipeline::{run_source, PipelineConfig};
use splitpoint::coordinator::remote::{EdgeClient, Server};
use splitpoint::coordinator::session::{
    Adaptive, MIN_BANDWIDTH_SAMPLE_BYTES, PolicyContext, SessionFrame, SplitPolicy, SplitSession,
};
use splitpoint::coordinator::{Engine, EngineRole};
use splitpoint::pointcloud::kitti::{self, KittiSource};
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::pointcloud::{FrameSource, PointCloud, ReplaySource};
use splitpoint::postprocess::Detection;
use splitpoint::voxel::Voxelizer;
use splitpoint::{Manifest, SplitPoint};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn manifest() -> Manifest {
    Manifest::load(&artifacts_dir()).expect("run `make artifacts` before cargo test")
}

/// One shared full engine for the whole test binary.
fn engine() -> Arc<Engine> {
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            SplitSession::builder()
                .artifacts(artifacts_dir())
                .build_engine()
                .expect("engine")
        })
        .clone()
}

fn clouds(seed0: u64, n: usize) -> Vec<PointCloud> {
    (0..n)
        .map(|i| SceneGenerator::with_seed(seed0 + i as u64).generate().cloud)
        .collect()
}

fn dets_bitwise_equal(a: &[Detection], b: &[Detection]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.class == y.class
                && x.score.to_bits() == y.score.to_bits()
                && x.boxx
                    .iter()
                    .zip(&y.boxx)
                    .all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

/// Test policy: plays a fixed split schedule, one entry per segment.
struct Scripted {
    splits: Vec<SplitPoint>,
    next: usize,
    every: usize,
}

impl SplitPolicy for Scripted {
    fn describe(&self) -> String {
        "scripted".to_string()
    }

    fn choose(&mut self, _ctx: &PolicyContext<'_>) -> anyhow::Result<SplitPoint> {
        let sp = self.splits[self.next % self.splits.len()];
        self.next += 1;
        Ok(sp)
    }

    fn interval(&self) -> usize {
        self.every
    }
}

/// A policy flipping splits mid-stream must yield, for every frame, the
/// identical detections a `Fixed` policy at that frame's chosen split
/// would produce — i.e. identical to `Engine::run_frame` at that split,
/// which the existing suites pin `Fixed` against. Serial and pipelined.
#[test]
fn scripted_policy_switching_matches_fixed_per_frame() {
    let e = engine();
    let schedule = vec![
        e.graph().split_by_name("vfe").unwrap(),
        e.graph().split_by_name("conv1").unwrap(),
        e.graph().split_by_name("edge_only").unwrap(),
        e.graph().split_by_name("vfe").unwrap(),
    ];
    let stream = clouds(4000, 8);
    for depth in [1usize, 3] {
        let mut session = SplitSession::builder()
            .engine(e.clone())
            .source(Box::new(ReplaySource::from_clouds(stream.clone())))
            .policy(Box::new(Scripted {
                splits: schedule.clone(),
                next: 0,
                every: 2,
            }))
            .pipeline_depth(depth)
            .build()
            .unwrap();
        let (frames, report) = session.run().unwrap();
        assert_eq!(frames.len(), stream.len(), "depth {depth}");
        assert_eq!(report.frames, stream.len());
        assert!(report.switches >= 2, "schedule must actually flip splits");
        for f in &frames {
            // segments of 2: frames 0-1 at vfe, 2-3 at conv1, 4-5 edge_only…
            let expect = schedule[(f.seq as usize / 2) % schedule.len()];
            assert_eq!(f.split, expect, "frame {} ran the scheduled split", f.seq);
            let serial = e
                .run_frame(&stream[f.source_seq as usize], f.split)
                .unwrap();
            assert!(
                dets_bitwise_equal(&f.output.detections, &serial.detections),
                "frame {} diverged from run_frame at split '{}' (depth {depth})",
                f.seq,
                f.split_label
            );
            assert_eq!(f.output.uplink_bytes, serial.timing.uplink_bytes);
            assert_eq!(f.output.uplink_v1_bytes, serial.timing.uplink_v1_bytes);
        }
    }
}

/// The adaptive policy (live-bandwidth cost model + hysteresis) may pick
/// any split it likes, but every frame must still be byte-identical to a
/// fixed run at whatever it picked.
#[test]
fn adaptive_policy_frames_match_fixed_at_chosen_splits() {
    let e = engine();
    let stream = clouds(5000, 6);
    let mut session = SplitSession::builder()
        .engine(e.clone())
        .source(Box::new(ReplaySource::from_clouds(stream.clone())))
        .policy(Box::new(Adaptive::new(Objective::InferenceTime).every(3)))
        .build()
        .unwrap();
    let (frames, report) = session.run().unwrap();
    assert_eq!(frames.len(), stream.len());
    if frames
        .iter()
        .any(|f| f.output.uplink_bytes >= MIN_BANDWIDTH_SAMPLE_BYTES)
    {
        assert!(
            report.bandwidth_bps.is_some(),
            "transport observed transfers"
        );
    }
    for f in &frames {
        let serial = e
            .run_frame(&stream[f.source_seq as usize], f.split)
            .unwrap();
        assert!(
            dets_bitwise_equal(&f.output.detections, &serial.detections),
            "frame {} diverged from fixed split '{}'",
            f.seq,
            f.split_label
        );
    }
}

/// KITTI `.bin` round trip: a generated scene written to disk and read
/// back through `FrameSource` must voxelize to exactly the grids of the
/// in-memory path (same occupancy, same sums) — the loader may not
/// perturb a single point.
#[test]
fn kitti_source_matches_in_memory_voxelization() {
    let m = manifest();
    let dir = std::env::temp_dir().join("splitpoint_session_kitti_fixture");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let scenes = clouds(6000, 3);
    for (i, cloud) in scenes.iter().enumerate() {
        kitti::write_bin(&dir.join(format!("{i:06}.bin")), cloud).unwrap();
    }

    let vox_disk = Voxelizer::from_config(&m.config);
    let vox_mem = Voxelizer::from_config(&m.config);
    let mut src = KittiSource::open(&dir).unwrap();
    assert_eq!(src.len_hint(), Some(scenes.len()));
    let mut seen = 0;
    while let Some(frame) = src.next_frame().unwrap() {
        let original = &scenes[frame.seq as usize];
        assert_eq!(
            frame.cloud.points, original.points,
            "scan {} round-tripped bit-exactly",
            frame.seq
        );
        let g_disk = vox_disk.voxelize(&frame.cloud);
        let g_mem = vox_mem.voxelize(original);
        assert_eq!(
            Voxelizer::occupied(&g_disk),
            Voxelizer::occupied(&g_mem),
            "occupancy parity for scan {}",
            frame.seq
        );
        assert_eq!(g_disk.in_range, g_mem.in_range);
        assert_eq!(g_disk.sum.data(), g_mem.sum.data());
        assert_eq!(g_disk.cnt.data(), g_mem.cnt.data());
        seen += 1;
    }
    assert_eq!(seen, scenes.len());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// `pipeline::run_source` streams a `FrameSource` directly: results equal
/// the serial path frame for frame.
#[test]
fn pipeline_consumes_frame_source_directly() {
    let e = engine();
    let sp = e.graph().split_by_name("vfe").unwrap();
    let stream = clouds(7000, 5);
    let mut src = ReplaySource::from_clouds(stream.clone());
    let (results, report) = run_source(
        e.clone(),
        sp,
        &mut src,
        PipelineConfig {
            depth: 3,
            tail_workers: 2,
        },
    )
    .unwrap();
    assert_eq!(results.len(), stream.len());
    assert_eq!(report.frames, stream.len());
    for (i, (got, cloud)) in results.iter().zip(&stream).enumerate() {
        let serial = e.run_frame(cloud, sp).unwrap();
        assert!(
            dets_bitwise_equal(&got.detections, &serial.detections),
            "frame {i} diverged through run_source"
        );
    }
}

/// Server-only mode: a tail-role engine defers the voxelizer (edge-side
/// scratch state) until a raw-offload request forces preprocessing onto
/// the server — and serves in-network splits without ever building it.
#[test]
fn server_tail_engine_builds_edge_state_lazily() {
    let m = manifest();
    let full = engine();
    let tail = Arc::new(
        Engine::with_runtime_role(
            &m,
            SystemConfig::paper(),
            full.runtime().clone(),
            EngineRole::ServerTail,
        )
        .unwrap(),
    );
    assert_eq!(tail.role(), EngineRole::ServerTail);
    assert!(!tail.voxelizer_ready(), "tail engine starts without edge state");

    let scene = SceneGenerator::with_seed(8100).generate();
    let sp = full.graph().split_by_name("vfe").unwrap();
    assert!(
        tail.head_stage(&scene.cloud, sp).is_err(),
        "tail engine must refuse head stages"
    );

    let server = Server::spawn("127.0.0.1:0", tail.clone()).unwrap();
    let mut client = EdgeClient::connect(server.addr(), full.clone()).unwrap();

    // in-network split: the tail half runs server-side, no voxelizer needed
    let local = full.run_frame(&scene.cloud, sp).unwrap();
    let (dets, timing) = client.run_frame(&scene.cloud, sp).unwrap();
    assert!(dets_bitwise_equal(&dets, &local.detections));
    assert_eq!(timing.uplink_v1_bytes, local.timing.uplink_v1_bytes);
    assert!(
        !tail.voxelizer_ready(),
        "vfe split never touches the server-side voxelizer"
    );

    // raw offload: preprocessing moves to the server, which lazily builds
    // the voxelizer on first use
    let raw = full.graph().split_by_name("raw").unwrap();
    let local_raw = full.run_frame(&scene.cloud, raw).unwrap();
    let (dets_raw, _) = client.run_frame(&scene.cloud, raw).unwrap();
    assert!(dets_bitwise_equal(&dets_raw, &local_raw.detections));
    assert!(tail.voxelizer_ready(), "raw offload builds it on demand");

    client.shutdown().unwrap();
    server.shutdown();
}

/// An edge-role engine refuses tail stages (the complementary guard).
#[test]
fn edge_head_engine_refuses_tail_stages() {
    let m = manifest();
    let full = engine();
    let edge = Engine::with_runtime_role(
        &m,
        SystemConfig::paper(),
        full.runtime().clone(),
        EngineRole::EdgeHead,
    )
    .unwrap();
    let scene = SceneGenerator::with_seed(8200).generate();
    let sp = edge.graph().split_by_name("vfe").unwrap();
    let head = edge.head_stage(&scene.cloud, sp).unwrap();
    let transferred = edge.transfer_stage(head).unwrap();
    assert!(edge.tail_stage(transferred).is_err());
}

/// The acceptance sweep: a KITTI `.bin` directory streamed end-to-end
/// through the session builder's TCP transport at pipeline depth 4
/// (`serve-edge --source kitti:<dir> --pipeline-depth 4`), byte-identical
/// to the in-process path, with the v1-vs-v2 wire accounting populated.
#[test]
fn kitti_directory_streams_through_tcp_session_at_depth_4() {
    let full = engine();
    let dir = std::env::temp_dir().join("splitpoint_session_kitti_tcp");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let scenes = clouds(9000, 6);
    for (i, cloud) in scenes.iter().enumerate() {
        kitti::write_bin(&dir.join(format!("{i:06}.bin")), cloud).unwrap();
    }

    let server = SplitSession::builder()
        .artifacts(artifacts_dir())
        .build_server("127.0.0.1:0")
        .unwrap();
    let addr = server.addr().to_string();

    let mut session = SplitSession::builder()
        .engine(full.clone())
        .source_spec(Some(&format!("kitti:{}", dir.display())), 1, None)
        .unwrap()
        .tcp(&addr)
        .pipeline_depth(4)
        .build()
        .unwrap();

    let sp = full.graph().split_by_name("vfe").unwrap();
    let mut count = 0usize;
    let report = session
        .run_with(|f: SessionFrame| {
            let local = full.run_frame(&scenes[f.source_seq as usize], sp).unwrap();
            assert!(
                dets_bitwise_equal(&f.output.detections, &local.detections),
                "scan {} diverged over the pipelined socket",
                f.source_seq
            );
            assert_eq!(f.output.uplink_bytes, local.timing.uplink_bytes);
            count += 1;
        })
        .unwrap();
    assert_eq!(count, scenes.len());
    assert_eq!(report.frames, scenes.len());
    assert!(report.uplink_bytes > 0);
    assert!(
        report.uplink_v1_bytes > 0,
        "v1 twin accounting must be populated for the EXPERIMENTS sweep"
    );
    assert!(report.wire_savings().is_some());
    assert!(report.bandwidth_bps.is_some(), "EWMA fed by real transfers");

    server.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// `--source` spec parsing errors are actionable.
#[test]
fn parse_source_rejects_unknown_specs() {
    use splitpoint::coordinator::session::parse_source;
    assert!(parse_source(Some("ftp:nope"), 1, None).is_err());
    assert!(parse_source(Some("kitti:/definitely/missing/dir"), 1, None).is_err());
    let mut synth = parse_source(None, 3, Some(2)).unwrap();
    assert_eq!(synth.len_hint(), Some(2));
    assert!(synth.next_frame().unwrap().is_some());
}
