//! `SplitSession` facade invariants (require `make artifacts`).
//!
//! The contract under test: a session is an *assembly* of source ×
//! transport × policy, never a semantic change. Whatever the policy
//! schedule, pipeline depth, or transport, per-frame detections must be
//! byte-identical to `Engine::run_frame` at the split the session chose
//! for that frame — no cross-frame state leakage when the split flips
//! mid-stream.

use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};

use splitpoint::bench::paper;
use splitpoint::config::SystemConfig;
use splitpoint::coordinator::adaptive::{self, Objective};
use splitpoint::coordinator::batcher::MultiSource;
use splitpoint::coordinator::fault::LinkHealth;
use splitpoint::coordinator::pipeline::{run_source, PipelineConfig};
use splitpoint::coordinator::remote::{EdgeClient, Server};
use splitpoint::coordinator::session::{
    Adaptive, Fixed, MIN_BANDWIDTH_SAMPLE_BYTES, PolicyContext, ServerSession, SessionFrame,
    SplitPolicy, SplitSession,
};
use splitpoint::coordinator::{Engine, EngineRole};
use splitpoint::pointcloud::kitti::{self, KittiSource, RecordedSource};
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::pointcloud::{FrameSource, PointCloud, ReplaySource};
use splitpoint::postprocess::Detection;
use splitpoint::voxel::Voxelizer;
use splitpoint::{Manifest, SplitPoint};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn manifest() -> Manifest {
    Manifest::load(&artifacts_dir()).expect("run `make artifacts` before cargo test")
}

/// One shared full engine for the whole test binary.
fn engine() -> Arc<Engine> {
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            SplitSession::builder()
                .artifacts(artifacts_dir())
                .build_engine()
                .expect("engine")
        })
        .clone()
}

fn clouds(seed0: u64, n: usize) -> Vec<PointCloud> {
    (0..n)
        .map(|i| SceneGenerator::with_seed(seed0 + i as u64).generate().cloud)
        .collect()
}

fn dets_bitwise_equal(a: &[Detection], b: &[Detection]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.class == y.class
                && x.score.to_bits() == y.score.to_bits()
                && x.boxx
                    .iter()
                    .zip(&y.boxx)
                    .all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

/// Test policy: plays a fixed split schedule, one entry per segment.
struct Scripted {
    splits: Vec<SplitPoint>,
    next: usize,
    every: usize,
}

impl SplitPolicy for Scripted {
    fn describe(&self) -> String {
        "scripted".to_string()
    }

    fn choose(&mut self, _ctx: &PolicyContext<'_>) -> anyhow::Result<SplitPoint> {
        let sp = self.splits[self.next % self.splits.len()];
        self.next += 1;
        Ok(sp)
    }

    fn interval(&self) -> usize {
        self.every
    }
}

/// A policy flipping splits mid-stream must yield, for every frame, the
/// identical detections a `Fixed` policy at that frame's chosen split
/// would produce — i.e. identical to `Engine::run_frame` at that split,
/// which the existing suites pin `Fixed` against. Serial and pipelined.
#[test]
fn scripted_policy_switching_matches_fixed_per_frame() {
    let e = engine();
    let schedule = vec![
        e.graph().split_by_name("vfe").unwrap(),
        e.graph().split_by_name("conv1").unwrap(),
        e.graph().split_by_name("edge_only").unwrap(),
        e.graph().split_by_name("vfe").unwrap(),
    ];
    let stream = clouds(4000, 8);
    for depth in [1usize, 3] {
        let mut session = SplitSession::builder()
            .engine(e.clone())
            .source(Box::new(ReplaySource::from_clouds(stream.clone())))
            .policy(Box::new(Scripted {
                splits: schedule.clone(),
                next: 0,
                every: 2,
            }))
            .pipeline_depth(depth)
            .build()
            .unwrap();
        let (frames, report) = session.run().unwrap();
        assert_eq!(frames.len(), stream.len(), "depth {depth}");
        assert_eq!(report.frames, stream.len());
        assert!(report.switches >= 2, "schedule must actually flip splits");
        for f in &frames {
            // segments of 2: frames 0-1 at vfe, 2-3 at conv1, 4-5 edge_only…
            let expect = schedule[(f.seq as usize / 2) % schedule.len()];
            assert_eq!(f.split, expect, "frame {} ran the scheduled split", f.seq);
            let serial = e
                .run_frame(&stream[f.source_seq as usize], f.split)
                .unwrap();
            assert!(
                dets_bitwise_equal(&f.output.detections, &serial.detections),
                "frame {} diverged from run_frame at split '{}' (depth {depth})",
                f.seq,
                f.split_label
            );
            assert_eq!(f.output.uplink_bytes, serial.timing.uplink_bytes);
            assert_eq!(f.output.uplink_v1_bytes, serial.timing.uplink_v1_bytes);
        }
    }
}

/// Satellite (PR 6): per-segment policy decisions land in
/// `SessionReport.segments` — one record per actual split change, in
/// stream order, with frames-per-segment summing to the stream length
/// and the policy's reason captured at the boundary that opened the
/// segment. A fixed-policy stream is one segment covering every frame.
#[test]
fn session_report_records_segments_with_reasons() {
    let e = engine();
    let schedule = vec![
        e.graph().split_by_name("vfe").unwrap(),
        e.graph().split_by_name("conv1").unwrap(),
        e.graph().split_by_name("edge_only").unwrap(),
        e.graph().split_by_name("vfe").unwrap(),
    ];
    let stream = clouds(22000, 8);
    let mut session = SplitSession::builder()
        .engine(e.clone())
        .source(Box::new(ReplaySource::from_clouds(stream.clone())))
        .policy(Box::new(Scripted {
            splits: schedule.clone(),
            next: 0,
            every: 2,
        }))
        .build()
        .unwrap();
    let (_, report) = session.run().unwrap();
    assert_eq!(report.frames, stream.len());
    assert_eq!(report.segments.len(), 4, "one record per split change");
    let labels: Vec<&str> = report.segments.iter().map(|s| s.split_label.as_str()).collect();
    assert_eq!(labels, ["vfe", "conv1", "edge_only", "vfe"]);
    for (i, seg) in report.segments.iter().enumerate() {
        assert_eq!(seg.index, i);
        assert_eq!(seg.frames, 2, "segment {i} frame count");
        assert_eq!(seg.split, schedule[i]);
        // Scripted keeps the default explain — its static description
        assert_eq!(seg.reason, "scripted");
    }
    assert_eq!(
        report.segments.iter().map(|s| s.frames).sum::<usize>(),
        report.frames,
        "per-segment frames partition the stream"
    );
    let table = report.segments_table().expect("segments recorded");
    assert!(table.contains("| 2 | edge_only | 2 | scripted |"), "table row:\n{table}");

    // a fixed-policy stream: exactly one segment, covering every frame
    let sp = e.graph().split_by_name("vfe").unwrap();
    let mut fixed = SplitSession::builder()
        .engine(e.clone())
        .source(Box::new(ReplaySource::from_clouds(stream.clone())))
        .policy(Box::new(Fixed(sp)))
        .build()
        .unwrap();
    let (_, report) = fixed.run().unwrap();
    assert_eq!(report.segments.len(), 1);
    assert_eq!(report.segments[0].frames, stream.len());
    assert_eq!(report.segments[0].reason, "fixed");
}

/// The adaptive policy (live-bandwidth cost model + hysteresis) may pick
/// any split it likes, but every frame must still be byte-identical to a
/// fixed run at whatever it picked.
#[test]
fn adaptive_policy_frames_match_fixed_at_chosen_splits() {
    let e = engine();
    let stream = clouds(5000, 6);
    let mut session = SplitSession::builder()
        .engine(e.clone())
        .source(Box::new(ReplaySource::from_clouds(stream.clone())))
        .policy(Box::new(Adaptive::new(Objective::InferenceTime).every(3)))
        .build()
        .unwrap();
    let (frames, report) = session.run().unwrap();
    assert_eq!(frames.len(), stream.len());
    if frames
        .iter()
        .any(|f| f.output.uplink_bytes >= MIN_BANDWIDTH_SAMPLE_BYTES)
    {
        assert!(
            report.bandwidth_bps.is_some(),
            "transport observed transfers"
        );
    }
    for f in &frames {
        let serial = e
            .run_frame(&stream[f.source_seq as usize], f.split)
            .unwrap();
        assert!(
            dets_bitwise_equal(&f.output.detections, &serial.detections),
            "frame {} diverged from fixed split '{}'",
            f.seq,
            f.split_label
        );
    }
}

/// KITTI `.bin` round trip: a generated scene written to disk and read
/// back through `FrameSource` must voxelize to exactly the grids of the
/// in-memory path (same occupancy, same sums) — the loader may not
/// perturb a single point.
#[test]
fn kitti_source_matches_in_memory_voxelization() {
    let m = manifest();
    let dir = std::env::temp_dir().join("splitpoint_session_kitti_fixture");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let scenes = clouds(6000, 3);
    for (i, cloud) in scenes.iter().enumerate() {
        kitti::write_bin(&dir.join(format!("{i:06}.bin")), cloud).unwrap();
    }

    let vox_disk = Voxelizer::from_config(&m.config);
    let vox_mem = Voxelizer::from_config(&m.config);
    let mut src = KittiSource::open(&dir).unwrap();
    assert_eq!(src.len_hint(), Some(scenes.len()));
    let mut seen = 0;
    while let Some(frame) = src.next_frame().unwrap() {
        let original = &scenes[frame.seq as usize];
        assert_eq!(
            frame.cloud.points, original.points,
            "scan {} round-tripped bit-exactly",
            frame.seq
        );
        let g_disk = vox_disk.voxelize(&frame.cloud);
        let g_mem = vox_mem.voxelize(original);
        assert_eq!(
            Voxelizer::occupied(&g_disk),
            Voxelizer::occupied(&g_mem),
            "occupancy parity for scan {}",
            frame.seq
        );
        assert_eq!(g_disk.in_range, g_mem.in_range);
        assert_eq!(g_disk.sum.data(), g_mem.sum.data());
        assert_eq!(g_disk.cnt.data(), g_mem.cnt.data());
        seen += 1;
    }
    assert_eq!(seen, scenes.len());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// `pipeline::run_source` streams a `FrameSource` directly: results equal
/// the serial path frame for frame.
#[test]
fn pipeline_consumes_frame_source_directly() {
    let e = engine();
    let sp = e.graph().split_by_name("vfe").unwrap();
    let stream = clouds(7000, 5);
    let mut src = ReplaySource::from_clouds(stream.clone());
    let (results, report) = run_source(
        e.clone(),
        sp,
        &mut src,
        PipelineConfig {
            depth: 3,
            tail_workers: 2,
        },
    )
    .unwrap();
    assert_eq!(results.len(), stream.len());
    assert_eq!(report.frames, stream.len());
    for (i, (got, cloud)) in results.iter().zip(&stream).enumerate() {
        let serial = e.run_frame(cloud, sp).unwrap();
        assert!(
            dets_bitwise_equal(&got.detections, &serial.detections),
            "frame {i} diverged through run_source"
        );
    }
}

/// Server-only mode: a tail-role engine defers the voxelizer (edge-side
/// scratch state) until a raw-offload request forces preprocessing onto
/// the server — and serves in-network splits without ever building it.
#[test]
fn server_tail_engine_builds_edge_state_lazily() {
    let m = manifest();
    let full = engine();
    let tail = Arc::new(
        Engine::with_runtime_role(
            &m,
            SystemConfig::paper(),
            full.runtime().clone(),
            EngineRole::ServerTail,
        )
        .unwrap(),
    );
    assert_eq!(tail.role(), EngineRole::ServerTail);
    assert!(!tail.voxelizer_ready(), "tail engine starts without edge state");

    let scene = SceneGenerator::with_seed(8100).generate();
    let sp = full.graph().split_by_name("vfe").unwrap();
    assert!(
        tail.head_stage(&scene.cloud, sp).is_err(),
        "tail engine must refuse head stages"
    );

    let server = Server::spawn("127.0.0.1:0", tail.clone()).unwrap();
    let mut client = EdgeClient::connect(server.addr(), full.clone()).unwrap();

    // in-network split: the tail half runs server-side, no voxelizer needed
    let local = full.run_frame(&scene.cloud, sp).unwrap();
    let (dets, timing) = client.run_frame(&scene.cloud, sp).unwrap();
    assert!(dets_bitwise_equal(&dets, &local.detections));
    assert_eq!(timing.uplink_v1_bytes, local.timing.uplink_v1_bytes);
    assert!(
        !tail.voxelizer_ready(),
        "vfe split never touches the server-side voxelizer"
    );

    // raw offload: preprocessing moves to the server, which lazily builds
    // the voxelizer on first use
    let raw = full.graph().split_by_name("raw").unwrap();
    let local_raw = full.run_frame(&scene.cloud, raw).unwrap();
    let (dets_raw, _) = client.run_frame(&scene.cloud, raw).unwrap();
    assert!(dets_bitwise_equal(&dets_raw, &local_raw.detections));
    assert!(tail.voxelizer_ready(), "raw offload builds it on demand");

    client.shutdown().unwrap();
    server.shutdown().unwrap();
}

/// An edge-role engine refuses tail stages (the complementary guard).
#[test]
fn edge_head_engine_refuses_tail_stages() {
    let m = manifest();
    let full = engine();
    let edge = Engine::with_runtime_role(
        &m,
        SystemConfig::paper(),
        full.runtime().clone(),
        EngineRole::EdgeHead,
    )
    .unwrap();
    let scene = SceneGenerator::with_seed(8200).generate();
    let sp = edge.graph().split_by_name("vfe").unwrap();
    let head = edge.head_stage(&scene.cloud, sp).unwrap();
    let transferred = edge.transfer_stage(head).unwrap();
    assert!(edge.tail_stage(transferred).is_err());
}

/// The acceptance sweep: a KITTI `.bin` directory streamed end-to-end
/// through the session builder's TCP transport at pipeline depth 4
/// (`serve-edge --source kitti:<dir> --pipeline-depth 4`), byte-identical
/// to the in-process path, with the v1-vs-v2 wire accounting populated.
#[test]
fn kitti_directory_streams_through_tcp_session_at_depth_4() {
    let full = engine();
    let dir = std::env::temp_dir().join("splitpoint_session_kitti_tcp");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let scenes = clouds(9000, 6);
    for (i, cloud) in scenes.iter().enumerate() {
        kitti::write_bin(&dir.join(format!("{i:06}.bin")), cloud).unwrap();
    }

    // the deprecated one-call server shim must keep working (it now routes
    // through ServerSession::builder)
    #[allow(deprecated)]
    let server = SplitSession::builder()
        .artifacts(artifacts_dir())
        .build_server("127.0.0.1:0")
        .unwrap();
    let addr = server.addr().to_string();

    let mut session = SplitSession::builder()
        .engine(full.clone())
        .source_spec(Some(&format!("kitti:{}", dir.display())), 1, None)
        .unwrap()
        .tcp(&addr)
        .pipeline_depth(4)
        .build()
        .unwrap();

    let sp = full.graph().split_by_name("vfe").unwrap();
    let mut count = 0usize;
    let report = session
        .run_with(|f: SessionFrame| {
            let local = full.run_frame(&scenes[f.source_seq as usize], sp).unwrap();
            assert!(
                dets_bitwise_equal(&f.output.detections, &local.detections),
                "scan {} diverged over the pipelined socket",
                f.source_seq
            );
            assert_eq!(f.output.uplink_bytes, local.timing.uplink_bytes);
            count += 1;
        })
        .unwrap();
    assert_eq!(count, scenes.len());
    assert_eq!(report.frames, scenes.len());
    assert!(report.uplink_bytes > 0);
    assert!(
        report.uplink_v1_bytes > 0,
        "v1 twin accounting must be populated for the EXPERIMENTS sweep"
    );
    assert!(report.wire_savings().is_some());
    assert!(report.bandwidth_bps.is_some(), "EWMA fed by real transfers");

    server.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// `--source` spec parsing errors are actionable.
#[test]
fn parse_source_rejects_unknown_specs() {
    use splitpoint::coordinator::session::parse_source;
    assert!(parse_source(Some("ftp:nope"), 1, None).is_err());
    assert!(parse_source(Some("kitti:/definitely/missing/dir"), 1, None).is_err());
    let mut synth = parse_source(None, 3, Some(2)).unwrap();
    assert_eq!(synth.len_hint(), Some(2));
    assert!(synth.next_frame().unwrap().is_some());
}

/// Fixed-policy test double that records the transport's in-flight
/// occupancy at every policy boundary — the probe for the
/// no-drain-at-segment-boundaries contract.
struct FixedProbing {
    sp: SplitPoint,
    every: usize,
    in_flight_log: Arc<Mutex<Vec<usize>>>,
}

impl SplitPolicy for FixedProbing {
    fn describe(&self) -> String {
        "fixed-probing".to_string()
    }

    fn choose(&mut self, ctx: &PolicyContext<'_>) -> anyhow::Result<SplitPoint> {
        self.in_flight_log.lock().unwrap().push(ctx.in_flight);
        Ok(self.sp)
    }

    fn interval(&self) -> usize {
        self.every
    }
}

/// The continuous-session contract (tentpole acceptance): a fixed-policy
/// stream never drains the transport's in-flight window at a segment
/// boundary. On the virtual-clock transport at depth 3 with 3-frame
/// segments, every boundary after the first must see occupancy > 0 —
/// and per-frame output must still be byte-identical to `run_frame`.
#[test]
fn fixed_policy_keeps_window_full_across_segment_boundaries() {
    let e = engine();
    let sp = e.graph().split_by_name("vfe").unwrap();
    let stream = clouds(16000, 10);
    let log = Arc::new(Mutex::new(Vec::new()));
    let mut session = SplitSession::builder()
        .engine(e.clone())
        .source(Box::new(ReplaySource::from_clouds(stream.clone())))
        .policy(Box::new(FixedProbing {
            sp,
            every: 3,
            in_flight_log: log.clone(),
        }))
        .pipeline_depth(3)
        .build()
        .unwrap();
    let (frames, report) = session.run().unwrap();
    assert_eq!(frames.len(), stream.len());
    assert_eq!(report.switches, 0, "fixed policy never flips");

    let log = log.lock().unwrap();
    assert_eq!(log.len(), 4, "boundaries at frames 0, 3, 6, 9");
    assert_eq!(log[0], 0, "nothing in flight before the first frame");
    for (i, &occ) in log.iter().enumerate().skip(1) {
        assert!(
            occ > 0,
            "boundary {i}: window drained to {occ} — the stream stalled at a segment \
             boundary instead of staying pipelined"
        );
    }

    for f in &frames {
        let serial = e.run_frame(&stream[f.source_seq as usize], sp).unwrap();
        assert!(
            dets_bitwise_equal(&f.output.detections, &serial.detections),
            "frame {} diverged under the continuous window",
            f.seq
        );
        assert_eq!(f.output.uplink_bytes, serial.timing.uplink_bytes);
    }
}

/// TCP acceptance sweep: a pipelined fixed-policy TCP session must be
/// byte-identical to `Engine::run_frame` at *every* split point — the
/// persistent stream handle (window kept full across boundaries) is pure
/// scheduling, never semantics, wherever the pipeline is cut.
#[test]
fn tcp_stream_matches_run_frame_at_every_split() {
    let full = engine();
    let server = ServerSession::builder()
        .listen("127.0.0.1:0")
        .artifacts(artifacts_dir())
        .build()
        .unwrap();
    let addr = server.addr().to_string();
    let stream = clouds(17000, 2);

    for sp in paper::paper_splits(&full).unwrap() {
        let label = full.graph().split_label(sp);
        let mut session = SplitSession::builder()
            .engine(full.clone())
            .source(Box::new(ReplaySource::from_clouds(stream.clone())))
            .policy(Box::new(Fixed(sp)))
            .tcp(&addr)
            .pipeline_depth(2)
            .build()
            .unwrap();
        let (frames, report) = session.run().unwrap();
        assert_eq!(frames.len(), stream.len(), "split '{label}'");
        assert_eq!(report.frames, stream.len());
        for f in &frames {
            let local = full.run_frame(&stream[f.source_seq as usize], sp).unwrap();
            assert!(
                dets_bitwise_equal(&f.output.detections, &local.detections),
                "frame {} diverged over the persistent TCP stream at split '{label}'",
                f.seq
            );
            // byte accounting matches wherever the live set is non-empty
            // (an empty set ships a ~9-byte protocol packet over TCP that
            // the virtual clock has no reason to charge)
            if local.timing.uplink_bytes > 0 {
                assert_eq!(f.output.uplink_bytes, local.timing.uplink_bytes, "split '{label}'");
                assert_eq!(
                    f.output.uplink_v1_bytes, local.timing.uplink_v1_bytes,
                    "split '{label}'"
                );
            }
        }
    }
    server.shutdown().unwrap();
}

/// Record → replay is lossless: a session teed through a `RecorderSink`
/// and a second session replaying the corpus produce byte-identical
/// detections with provenance (sensor, seq, points) intact — for both a
/// synthetic stream and a KITTI `.bin` fixture directory. This is the
/// local twin of the CI `replay-corpus` lane.
#[test]
fn record_replay_roundtrip_is_lossless() {
    let e = engine();
    let base = std::env::temp_dir().join("splitpoint_session_record_replay");
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).unwrap();

    // ---- source A: synthetic; source B: a KITTI fixture directory
    let synth = clouds(18000, 3);
    let kitti_dir = base.join("kitti_fixture");
    std::fs::create_dir_all(&kitti_dir).unwrap();
    let kitti_clouds = clouds(18500, 2);
    for (i, cloud) in kitti_clouds.iter().enumerate() {
        kitti::write_bin(&kitti_dir.join(format!("{i:06}.bin")), cloud).unwrap();
    }
    let cases: Vec<(&str, Box<dyn FrameSource>)> = vec![
        (
            "synthetic",
            Box::new(ReplaySource::from_clouds(synth.clone())),
        ),
        ("kitti", Box::new(KittiSource::open(&kitti_dir).unwrap())),
    ];

    for (name, source) in cases {
        let corpus = base.join(format!("corpus_{name}"));
        let mut recording = SplitSession::builder()
            .engine(e.clone())
            .source(source)
            .record_to(&corpus)
            .pipeline_depth(2)
            .build()
            .unwrap();
        let (orig, _) = recording.run().unwrap();
        assert!(!orig.is_empty(), "{name}: recorded session streamed frames");
        assert!(corpus.join("manifest.json").is_file(), "{name}: manifest written");
        let direct = RecordedSource::open(&corpus).unwrap();
        assert_eq!(direct.len_hint(), Some(orig.len()), "{name}: corpus is complete");

        // replay through the CLI spec path (exercises parse_source too)
        let mut replay = SplitSession::builder()
            .engine(e.clone())
            .source_spec(Some(&format!("replay:{}", corpus.display())), 1, None)
            .unwrap()
            .pipeline_depth(2)
            .build()
            .unwrap();
        let (replayed, _) = replay.run().unwrap();
        assert_eq!(replayed.len(), orig.len(), "{name}: frame count preserved");
        for (a, b) in orig.iter().zip(&replayed) {
            assert_eq!(a.sensor_id, b.sensor_id, "{name}: sensor tag preserved");
            assert_eq!(a.source_seq, b.source_seq, "{name}: source seq preserved");
            assert_eq!(a.points, b.points, "{name}: point count preserved");
            assert!(
                dets_bitwise_equal(&a.output.detections, &b.output.detections),
                "{name}: frame {} detections diverged through record→replay",
                a.seq
            );
            assert_eq!(a.output.uplink_bytes, b.output.uplink_bytes, "{name}");
        }
    }
    std::fs::remove_dir_all(&base).unwrap();
}

/// Multi-sensor fan-in: two replay "sensors" of unequal length
/// round-robin through the batcher with per-sensor tagging intact, the
/// report accounts frames per sensor, and every frame remains
/// byte-identical to `run_frame` on its own cloud.
#[test]
fn multi_sensor_fan_in_round_robins_and_matches_run_frame() {
    let e = engine();
    let s0 = clouds(19000, 3);
    let s1 = clouds(19500, 2);
    let multi = MultiSource::round_robin(vec![
        Box::new(ReplaySource::from_clouds(s0.clone())),
        Box::new(ReplaySource::from_clouds(s1.clone())),
    ]);
    let mut session = SplitSession::builder()
        .engine(e.clone())
        .source(Box::new(multi))
        .pipeline_depth(2)
        .build()
        .unwrap();
    let (frames, report) = session.run().unwrap();

    let tags: Vec<(u32, u64)> = frames.iter().map(|f| (f.sensor_id, f.source_seq)).collect();
    assert_eq!(
        tags,
        [(0, 0), (1, 0), (0, 1), (1, 1), (0, 2)],
        "round-robin interleave, sensor 1 drops out when exhausted"
    );
    assert_eq!(report.sensor_usage.get(&0), Some(&3));
    assert_eq!(report.sensor_usage.get(&1), Some(&2));
    assert!(report.summary().contains("sensors"), "summary reports the fan-in");

    for f in &frames {
        let cloud = match f.sensor_id {
            0 => &s0[f.source_seq as usize],
            _ => &s1[f.source_seq as usize],
        };
        let serial = e.run_frame(cloud, f.split).unwrap();
        assert!(
            dets_bitwise_equal(&f.output.detections, &serial.detections),
            "sensor {} frame {} diverged through the fan-in",
            f.sensor_id,
            f.source_seq
        );
    }
}

/// `Adaptive` flip damping: a hysteresis margin keeps the policy at the
/// current split when the projected win is below the margin, and the
/// post-switch cooldown refuses a second flip for the configured number
/// of evaluations even with the margin at zero.
#[test]
fn adaptive_hysteresis_and_cooldown_refuse_flips() {
    let e = engine();
    let cloud = SceneGenerator::with_seed(20000).generate().cloud;
    let edge_only = e.graph().split_edge_only();
    let ctx = |current: Option<SplitPoint>| PolicyContext {
        engine: &*e,
        cloud: &cloud,
        frames_done: 0,
        bandwidth_bps: None,
        current,
        in_flight: 0,
        health: LinkHealth::default(),
        sla: Default::default(),
    };

    // precondition: under the default link, running everything on the
    // slow edge is NOT the inference-time optimum (the paper's headline)
    let best = adaptive::choose_split(&e, &cloud, Objective::InferenceTime).unwrap().split;
    assert_ne!(best, edge_only, "test precondition");

    // an absurd hysteresis margin: no win is ever big enough to switch
    let mut sticky = Adaptive::new(Objective::InferenceTime).hysteresis(1e9);
    assert_eq!(
        sticky.choose(&ctx(Some(edge_only))).unwrap(),
        edge_only,
        "hysteresis refuses the flip"
    );
    // zero margin: the same situation flips to the optimum
    let mut eager = Adaptive::new(Objective::InferenceTime).hysteresis(0.0);
    assert_eq!(eager.choose(&ctx(Some(edge_only))).unwrap(), best);

    // cooldown 1: first evaluation switches, the next one is frozen at
    // the current split, the one after that may switch again
    let mut cooled = Adaptive::new(Objective::InferenceTime)
        .hysteresis(0.0)
        .cooldown(1);
    assert_eq!(cooled.choose(&ctx(Some(edge_only))).unwrap(), best, "switches");
    assert_eq!(
        cooled.choose(&ctx(Some(edge_only))).unwrap(),
        edge_only,
        "within the cooldown window the flip is refused"
    );
    assert_eq!(
        cooled.choose(&ctx(Some(edge_only))).unwrap(),
        best,
        "cooldown expired"
    );
}

/// `Adaptive::explain` narrates the most recent decision: initial pick,
/// switch past the hysteresis margin, hold within it, and cooldown
/// freeze — the strings the per-segment report records.
#[test]
fn adaptive_explain_reports_decision_reasons() {
    let e = engine();
    let cloud = SceneGenerator::with_seed(21000).generate().cloud;
    let edge_only = e.graph().split_edge_only();
    let ctx = |current: Option<SplitPoint>| PolicyContext {
        engine: &*e,
        cloud: &cloud,
        frames_done: 0,
        bandwidth_bps: None,
        current,
        in_flight: 0,
        health: LinkHealth::default(),
        sla: Default::default(),
    };
    let best = adaptive::choose_split(&e, &cloud, Objective::InferenceTime).unwrap().split;
    assert_ne!(best, edge_only, "test precondition");

    let mut fresh = Adaptive::new(Objective::InferenceTime);
    assert_eq!(fresh.explain(), fresh.describe(), "no evaluation yet");
    fresh.choose(&ctx(None)).unwrap();
    assert!(
        fresh.explain().starts_with("initial pick"),
        "got: {}",
        fresh.explain()
    );

    let mut sticky = Adaptive::new(Objective::InferenceTime).hysteresis(1e9);
    sticky.choose(&ctx(Some(edge_only))).unwrap();
    assert!(sticky.explain().starts_with("held"), "got: {}", sticky.explain());

    let mut eager = Adaptive::new(Objective::InferenceTime).hysteresis(0.0);
    eager.choose(&ctx(Some(edge_only))).unwrap();
    assert!(eager.explain().starts_with("switched"), "got: {}", eager.explain());

    let mut cooled = Adaptive::new(Objective::InferenceTime)
        .hysteresis(0.0)
        .cooldown(1);
    cooled.choose(&ctx(Some(edge_only))).unwrap();
    cooled.choose(&ctx(Some(edge_only))).unwrap();
    assert!(
        cooled.explain().contains("cooldown"),
        "got: {}",
        cooled.explain()
    );
}

/// Satellite (PR 9): `Adaptive` *acts* on link degradation instead of
/// only narrating it. With the measured RTT far above the configured
/// baseline — or any SLA objective breached — the policy prefers the
/// smallest-uplink split inside its hysteresis cost band, and the
/// explain string records the degraded preference.
#[test]
fn adaptive_prefers_smaller_uplink_on_degraded_link() {
    use splitpoint::metrics::SimTime;
    use splitpoint::telemetry::sla::{SlaKind, SlaStatus, SlaVerdict};

    let e = engine();
    let cloud = SceneGenerator::with_seed(23000).generate().cloud;
    let estimates = adaptive::estimate_splits(&e, &cloud).unwrap();
    let uplink_of = |sp: SplitPoint| {
        estimates
            .iter()
            .find(|est| est.split == sp)
            .map(|est| est.uplink_bytes)
            .expect("estimated split")
    };
    let ctx = |health: LinkHealth, sla: SlaVerdict| PolicyContext {
        engine: &*e,
        cloud: &cloud,
        frames_done: 0,
        bandwidth_bps: None,
        current: None,
        in_flight: 0,
        health,
        sla,
    };

    // a wide hysteresis band gives the degraded preference room to move
    let mut policy = Adaptive::new(Objective::InferenceTime).hysteresis(0.5);
    let clean = policy
        .choose(&ctx(LinkHealth::default(), SlaVerdict::default()))
        .unwrap();

    // scripted degraded link: measured RTT 100x the configured two-leg
    // baseline trips the preference
    let inflated = SimTime::from_secs_f64(1.0 + 100.0 * 2.0 * e.link().config().rtt_one_way);
    let mut policy = Adaptive::new(Objective::InferenceTime).hysteresis(0.5);
    let degraded = policy
        .choose(&ctx(
            LinkHealth {
                rtt: Some(inflated),
                ..Default::default()
            },
            SlaVerdict::default(),
        ))
        .unwrap();
    assert!(
        uplink_of(degraded) <= uplink_of(clean),
        "degraded link picked a larger uplink ({} > {})",
        uplink_of(degraded),
        uplink_of(clean)
    );
    assert!(
        policy.explain().contains("degraded (RTT inflated)"),
        "got: {}",
        policy.explain()
    );

    // an SLA breach alone (no RTT sample at all) trips the same preference
    let breached = SlaVerdict {
        statuses: vec![SlaStatus {
            kind: SlaKind::LatencyBound,
            value: 1.0,
            threshold: 0.1,
            breached: true,
        }],
    };
    let mut policy = Adaptive::new(Objective::InferenceTime).hysteresis(0.5);
    let under_breach = policy.choose(&ctx(LinkHealth::default(), breached)).unwrap();
    assert!(uplink_of(under_breach) <= uplink_of(clean));
    assert!(
        policy.explain().contains("degraded (SLA breached)"),
        "got: {}",
        policy.explain()
    );
}
