//! Parallel reference-executor equivalence suite (PR 3 contract):
//!
//! * `threads ∈ {1, 2, 4}` produce **byte-identical** module outputs and
//!   end-to-end detections across every manifest split point — the worker
//!   pool partitions independent output rows, it never re-associates a
//!   float reduction, so parallelism is scheduling, not semantics;
//! * the kernel scratch arenas stop growing after warmup — steady-state
//!   execution allocates nothing for patch/accumulator buffers;
//! * (PR 6) the pin extends three ways: SIMD-dispatched, forced-scalar,
//!   and pre-refactor legacy kernels produce byte-identical module
//!   outputs, and SIMD vs scalar detections match across every split at
//!   threads {1, 2, max} — including adversarial-occupancy frames that
//!   exercise the per-tap mask-skip path (empty, single site, dense
//!   block).

use std::path::PathBuf;
use std::sync::Arc;

use splitpoint::config::SystemConfig;
use splitpoint::coordinator::Engine;
use splitpoint::model::graph::NodeKind;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::pointcloud::{Point, PointCloud};
use splitpoint::postprocess::Detection;
use splitpoint::runtime::reference::ReferenceModel;
use splitpoint::runtime::simd::SimdMode;
use splitpoint::runtime::XlaRuntime;
use splitpoint::tensor::Tensor;
use splitpoint::Manifest;

fn load_manifest() -> Manifest {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(&dir).expect("artifact manifest")
}

/// Engine over an explicitly-dispatched runtime (the builder's
/// `.simd(mode)` path, without needing an artifacts working directory).
fn engine_with(manifest: &Manifest, threads: usize, simd: SimdMode) -> Engine {
    let runtime = Arc::new(XlaRuntime::load_with(manifest, threads, simd).unwrap());
    Engine::with_runtime(manifest, SystemConfig::paper(), runtime).unwrap()
}

fn max_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Bitwise equality — not allclose. Thread count must not move a single
/// ULP.
fn dets_identical(a: &[Detection], b: &[Detection]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.class == y.class
                && x.score.to_bits() == y.score.to_bits()
                && x.boxx
                    .iter()
                    .zip(&y.boxx)
                    .all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

#[test]
fn thread_counts_produce_byte_identical_module_outputs() {
    let manifest = load_manifest();
    let scene = SceneGenerator::with_seed(42).generate();
    let e1 = Engine::new_threaded(&manifest, SystemConfig::paper(), 1).unwrap();
    let (store, _) = e1.profile_frame(&scene.cloud).unwrap();
    for threads in [2usize, 4] {
        let en = Engine::new_threaded(&manifest, SystemConfig::paper(), threads).unwrap();
        assert_eq!(en.runtime().threads(), threads);
        for node in e1.graph().nodes() {
            if node.kind != NodeKind::Xla {
                continue;
            }
            let inputs: Vec<Arc<Tensor>> = node
                .input_ids()
                .iter()
                .map(|&id| store.get(id).expect("profiled input").clone())
                .collect();
            let a = e1.runtime().execute(&node.name, &inputs).unwrap();
            let b = en.runtime().execute(&node.name, &inputs).unwrap();
            assert_eq!(
                a, b,
                "module '{}' diverged between threads=1 and threads={threads}",
                node.name
            );
            for (ta, tb) in a.iter().zip(&b) {
                assert_eq!(
                    ta.site_index(),
                    tb.site_index(),
                    "site index of '{}' diverged at threads={threads}",
                    node.name
                );
            }
        }
    }
}

#[test]
fn thread_counts_produce_identical_detections_at_every_split() {
    let manifest = load_manifest();
    let scene = SceneGenerator::with_seed(7).generate();
    let e1 = Engine::new_threaded(&manifest, SystemConfig::paper(), 1).unwrap();
    let engines: Vec<Engine> = [2usize, 4]
        .iter()
        .map(|&t| Engine::new_threaded(&manifest, SystemConfig::paper(), t).unwrap())
        .collect();
    for sp in e1.graph().all_splits() {
        let base = e1.run_frame(&scene.cloud, sp).unwrap();
        for (en, t) in engines.iter().zip([2usize, 4]) {
            let r = en.run_frame(&scene.cloud, sp).unwrap();
            assert!(
                dets_identical(&r.detections, &base.detections),
                "split '{}': detections diverged between threads=1 and threads={t}",
                e1.graph().split_label(sp)
            );
            // the wire crossing is identical too: same tensors, same codec
            assert_eq!(
                r.timing.uplink_bytes,
                base.timing.uplink_bytes,
                "split '{}' wire bytes diverged at threads={t}",
                e1.graph().split_label(sp)
            );
        }
    }
}

#[test]
fn pipelined_threaded_engine_matches_serial() {
    use splitpoint::coordinator::pipeline::{self, PipelineConfig};
    let manifest = load_manifest();
    let engine =
        Arc::new(Engine::new_threaded(&manifest, SystemConfig::paper(), 2).unwrap());
    let sp = engine.graph().split_after("vfe").unwrap();
    let clouds: Vec<_> = (0..4)
        .map(|i| SceneGenerator::with_seed(200 + i).generate().cloud)
        .collect();
    let serial: Vec<_> = clouds
        .iter()
        .map(|c| engine.run_frame(c, sp).unwrap())
        .collect();
    let (piped, _report) = pipeline::run_stream(
        engine.clone(),
        sp,
        &clouds,
        PipelineConfig {
            depth: 2,
            tail_workers: 2,
        },
    )
    .unwrap();
    assert_eq!(piped.len(), serial.len());
    for (p, s) in piped.iter().zip(&serial) {
        assert!(
            dets_identical(&p.detections, &s.detections),
            "kernel threads + pipeline tails must stay bit-identical to serial"
        );
    }
}

/// The PR 3 `threads=N == threads=1` harness extended to a three-way
/// pin: every Xla module's outputs under the SIMD-dispatched engine, the
/// forced-scalar engine, and the pre-refactor legacy kernels are
/// byte-identical at threads {1, 2, max}. On hosts without a vector unit
/// `auto` resolves to scalar and the comparison is still meaningful —
/// gather-GEMM + masks vs the legacy direct kernels.
#[test]
fn simd_scalar_and_legacy_module_outputs_are_byte_identical() {
    let manifest = load_manifest();
    let scene = SceneGenerator::with_seed(42).generate();
    let legacy = ReferenceModel::new(&manifest).unwrap();
    let e1 = engine_with(&manifest, 1, SimdMode::Auto);
    let (store, _) = e1.profile_frame(&scene.cloud).unwrap();
    for threads in [1usize, 2, max_threads()] {
        let auto = engine_with(&manifest, threads, SimdMode::Auto);
        let scalar = engine_with(&manifest, threads, SimdMode::Scalar);
        for node in e1.graph().nodes() {
            if node.kind != NodeKind::Xla {
                continue;
            }
            let inputs: Vec<Arc<Tensor>> = node
                .input_ids()
                .iter()
                .map(|&id| store.get(id).expect("profiled input").clone())
                .collect();
            let a = auto.runtime().execute(&node.name, &inputs).unwrap();
            let s = scalar.runtime().execute(&node.name, &inputs).unwrap();
            assert_eq!(
                a, s,
                "module '{}' diverged between simd=auto and simd=scalar at threads={threads}",
                node.name
            );
            let idx = legacy.module_index(&node.name).expect("legacy module");
            let l = legacy.execute_legacy(idx, &inputs).unwrap();
            assert_eq!(
                a, l,
                "module '{}' diverged between simd=auto and the legacy kernels at threads={threads}",
                node.name
            );
        }
    }
}

/// Satellite 3 — adversarial occupancy for the per-tap mask-skip path: a
/// fully-empty frame (every 3×3×3 neighborhood absent), a single
/// occupied site, and a dense block must all produce detections and wire
/// bytes bitwise-equal between SIMD and forced-scalar dispatch across
/// every split at threads {1, 2, max}; the sparse frames must actually
/// take the skip path (tap telemetry sees absent taps).
#[test]
fn mask_skip_frames_match_scalar_across_splits_and_threads() {
    let manifest = load_manifest();
    let single = PointCloud {
        points: vec![Point { x: 12.0, y: 0.5, z: -1.0, intensity: 0.4 }],
    };
    let mut block = Vec::new();
    for i in 0..12 {
        for j in 0..12 {
            for k in 0..4 {
                block.push(Point {
                    x: 10.0 + i as f32 * 0.2,
                    y: -1.0 + j as f32 * 0.2,
                    z: -1.6 + k as f32 * 0.4,
                    intensity: 0.1 + i as f32 * 0.01 + j as f32 * 0.02,
                });
            }
        }
    }
    let clouds = [
        ("empty", PointCloud::default()),
        ("single-site", single),
        ("dense-block", PointCloud { points: block }),
    ];
    for threads in [1usize, 2, max_threads()] {
        let auto = engine_with(&manifest, threads, SimdMode::Auto);
        let scalar = engine_with(&manifest, threads, SimdMode::Scalar);
        for (kind, cloud) in &clouds {
            for sp in auto.graph().all_splits() {
                let a = auto.run_frame(cloud, sp).unwrap();
                let s = scalar.run_frame(cloud, sp).unwrap();
                assert!(
                    dets_identical(&a.detections, &s.detections),
                    "{kind} frame: detections diverged between simd=auto and \
                     simd=scalar at split '{}' threads={threads}",
                    auto.graph().split_label(sp)
                );
                assert_eq!(
                    a.timing.uplink_bytes,
                    s.timing.uplink_bytes,
                    "{kind} frame: wire bytes diverged at split '{}' threads={threads}",
                    auto.graph().split_label(sp)
                );
            }
        }
        let (seen, skipped) = auto.runtime().tap_stats();
        assert!(seen > 0, "conv stages saw no taps at threads={threads}");
        assert!(
            skipped > 0,
            "sparse frames left no absent taps to skip at threads={threads}"
        );
        assert!(skipped < seen, "a dense block cannot skip every tap");
    }
}

#[test]
fn scratch_arena_does_not_grow_in_steady_state() {
    let manifest = load_manifest();
    let engine = Engine::new_threaded(&manifest, SystemConfig::paper(), 2).unwrap();
    let scene = SceneGenerator::with_seed(31).generate();
    let (store, _) = engine.profile_frame(&scene.cloud).unwrap();
    // the scratch-using modules: every 3D conv stage + the BEV backbone
    let kernel_nodes: Vec<(String, Vec<Arc<Tensor>>)> = engine
        .graph()
        .nodes()
        .iter()
        .filter(|n| n.kind == NodeKind::Xla && n.name != "vfe" && n.name != "roi_head")
        .map(|n| {
            let inputs = n
                .input_ids()
                .iter()
                .map(|&id| store.get(id).expect("profiled input").clone())
                .collect();
            (n.name.clone(), inputs)
        })
        .collect();
    assert!(!kernel_nodes.is_empty());
    let one_frame = |i: usize| {
        for (name, inputs) in &kernel_nodes {
            let out = engine.runtime().execute(name, inputs).unwrap();
            assert!(!out.is_empty(), "frame {i}: '{name}' produced nothing");
        }
    };
    for i in 0..5 {
        one_frame(i); // warmup: arenas grow to the working-set size
    }
    let warm = engine.runtime().scratch_stats();
    assert!(warm.0 >= 1, "no arenas pooled after warmup");
    assert!(warm.1 > 0, "pooled arenas hold no capacity");
    for i in 5..100 {
        one_frame(i);
    }
    assert_eq!(
        engine.runtime().scratch_stats(),
        warm,
        "kernel scratch arenas grew after warmup (steady state must not allocate)"
    );
}
