//! Property-based tests over the coordinator invariants (no artifacts
//! needed — these run on synthetic tensors and the declared module graph).

use splitpoint::metrics::{SimTime, Stats};
use splitpoint::model::graph::{Node, NodeKind, PipelineGraph, PRIMAL};
use splitpoint::postprocess::nms::{bev_iou, nms_bev};
use splitpoint::postprocess::Detection;
use splitpoint::prop_assert;
use splitpoint::tensor::codec::{Packet, Policy};
use splitpoint::tensor::Tensor;
use splitpoint::testing::{check, default_cases};
use splitpoint::util::json;
use splitpoint::util::rng::Rng;

// ------------------------------------------------------------- generators

fn random_shape(rng: &mut Rng) -> Vec<usize> {
    let rank = rng.range(1, 4) as usize;
    (0..rank).map(|_| rng.range(1, 12) as usize).collect()
}

fn random_tensor(rng: &mut Rng, occupancy: f64) -> Tensor {
    let shape = random_shape(rng);
    let mut t = Tensor::zeros(&shape);
    let c = t.channels();
    let spatial = t.spatial();
    for s in 0..spatial {
        if rng.chance(occupancy) {
            for ch in 0..c {
                t.data_mut()[s * c + ch] = rng.normal() as f32 * 3.0;
            }
        }
    }
    t
}

fn random_mask(rng: &mut Rng, occupancy: f64) -> Tensor {
    let mut shape = random_shape(rng);
    *shape.last_mut().unwrap() = 1;
    let mut t = Tensor::zeros(&shape);
    for x in t.data_mut() {
        *x = f32::from(rng.chance(occupancy));
    }
    t
}

fn random_box(rng: &mut Rng) -> [f32; 7] {
    [
        rng.uniform(0.0, 46.0) as f32,
        rng.uniform(-23.0, 23.0) as f32,
        rng.uniform(-3.0, 1.0) as f32,
        rng.uniform(0.3, 6.0) as f32,
        rng.uniform(0.3, 3.0) as f32,
        rng.uniform(0.3, 3.0) as f32,
        rng.uniform(-3.15, 3.15) as f32,
    ]
}

// ------------------------------------------------------------------ codec

#[test]
fn prop_codec_roundtrip_exact_policies() {
    check("codec roundtrip", default_cases(), |rng| {
        let occ = rng.f64();
        let t = random_tensor(rng, occ);
        let m = random_mask(rng, occ);
        let p = Packet::new(vec![("f".into(), t.clone()), ("m".into(), m.clone())]);
        for policy in [Policy::Auto, Policy::Dense] {
            let back = Packet::decode(&p.encode(policy))
                .map_err(|e| format!("decode failed: {e}"))?;
            prop_assert!(back.get("f") == Some(&t), "feature tensor mutated ({policy:?})");
            prop_assert!(back.get("m") == Some(&m), "mask tensor mutated ({policy:?})");
        }
        Ok(())
    });
}

#[test]
fn prop_codec_auto_never_larger_than_dense() {
    check("auto <= dense", default_cases(), |rng| {
        let occ = rng.f64();
        let t = random_tensor(rng, occ);
        let p = Packet::new(vec![("t".into(), t)]);
        let auto = p.encode(Policy::Auto).len();
        let dense = p.encode(Policy::Dense).len();
        prop_assert!(auto <= dense, "auto {auto} > dense {dense}");
        Ok(())
    });
}

#[test]
fn prop_codec_size_reporting_is_exact() {
    check("encoded_size == len", default_cases(), |rng| {
        let occ_t = rng.f64();
        let occ_m = rng.f64();
        let t = random_tensor(rng, occ_t);
        let m = random_mask(rng, occ_m);
        let p = Packet::new(vec![("a".into(), t), ("b".into(), m)]);
        for policy in [Policy::Auto, Policy::Dense, Policy::AutoQuantized] {
            prop_assert!(
                p.encode(policy).len() == p.encoded_size(policy),
                "size mismatch under {policy:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_quantization_error_bounded_by_step() {
    check("quant error bound", default_cases(), |rng| {
        let t = random_tensor(rng, 1.0);
        let p = Packet::new(vec![("t".into(), t.clone())]);
        let back = Packet::decode(&p.encode(Policy::AutoQuantized))
            .map_err(|e| format!("{e}"))?;
        let q = back.get("t").unwrap();
        let step = t.abs_max() / 127.0;
        let err = t.max_abs_diff(q).unwrap();
        prop_assert!(
            err <= step * 0.5 + 1e-6,
            "quant error {err} > half-step {}",
            step * 0.5
        );
        Ok(())
    });
}

#[test]
fn prop_sparse_bytes_monotone_in_occupancy() {
    check("sparse monotone", default_cases(), |rng| {
        let shape = [4usize, 8, 8, rng.range(1, 8) as usize];
        let occ_lo = rng.f64() * 0.5;
        let occ_hi = occ_lo + rng.f64() * 0.5;
        // nested occupancy: hi's active set contains lo's
        let mut lo = Tensor::zeros(&shape);
        let mut hi = Tensor::zeros(&shape);
        let c = lo.channels();
        for s in 0..lo.spatial() {
            let u = rng.f64();
            let v = rng.normal() as f32 + 2.0;
            if u < occ_lo {
                for ch in 0..c {
                    lo.data_mut()[s * c + ch] = v;
                }
            }
            if u < occ_hi {
                for ch in 0..c {
                    hi.data_mut()[s * c + ch] = v;
                }
            }
        }
        let b_lo = Packet::new(vec![("t".into(), lo)]).encode(Policy::Auto).len();
        let b_hi = Packet::new(vec![("t".into(), hi)]).encode(Policy::Auto).len();
        prop_assert!(b_lo <= b_hi, "bytes not monotone: {b_lo} > {b_hi}");
        Ok(())
    });
}

// ------------------------------------------------------------------- nms

#[test]
fn prop_nms_kept_set_is_mutually_disjoint() {
    check("nms disjoint", default_cases(), |rng| {
        let n = rng.range(1, 40) as usize;
        let mut dets: Vec<Detection> = (0..n)
            .map(|_| Detection {
                score: rng.f32(),
                boxx: random_box(rng),
                class: rng.below(3),
            })
            .collect();
        dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        let thr = (rng.f64() * 0.9) as f32 + 0.05;
        let keep = nms_bev(&dets, thr, 100);
        for (i, &a) in keep.iter().enumerate() {
            for &b in &keep[i + 1..] {
                let iou = bev_iou(&dets[a].boxx, &dets[b].boxx);
                prop_assert!(
                    iou <= thr as f64 + 1e-9,
                    "kept boxes {a},{b} overlap iou={iou} > {thr}"
                );
            }
        }
        // keep order must be by descending score
        for w in keep.windows(2) {
            prop_assert!(
                dets[w[0]].score >= dets[w[1]].score,
                "keep not score-sorted"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_iou_is_symmetric_and_bounded() {
    check("iou symmetric", default_cases(), |rng| {
        let a = random_box(rng);
        let b = random_box(rng);
        let ab = bev_iou(&a, &b);
        let ba = bev_iou(&b, &a);
        prop_assert!((ab - ba).abs() < 1e-6, "asymmetric: {ab} vs {ba}");
        prop_assert!((0.0..=1.0 + 1e-9).contains(&ab), "iou {ab} out of range");
        let aa = bev_iou(&a, &a);
        prop_assert!((aa - 1.0).abs() < 1e-6, "self-iou {aa} != 1");
        Ok(())
    });
}

// ------------------------------------------------- graph / liveness (T2)

fn random_chain_graph(rng: &mut Rng) -> PipelineGraph {
    // a random linear chain with occasional skip connections, modelling the
    // conv2/3/4 -> roi_head pattern; always ends in the final outputs
    let n = rng.range(3, 9) as usize;
    let mut nodes = Vec::new();
    let mut produced: Vec<String> = vec![PRIMAL.to_string()];
    for i in 0..n {
        let mut inputs = vec![produced.last().unwrap().clone()];
        // random skip input from earlier
        if produced.len() > 2 && rng.chance(0.4) {
            let j = rng.below(produced.len() - 1);
            if !inputs.contains(&produced[j]) {
                inputs.push(produced[j].clone());
            }
        }
        let outputs = if i == n - 1 {
            vec![
                "roi_scores".to_string(),
                "roi_boxes".to_string(),
                "roi_classes".to_string(),
            ]
        } else {
            vec![format!("t{i}")]
        };
        produced.extend(outputs.iter().cloned());
        nodes.push(Node::new(format!("n{i}"), NodeKind::Xla, inputs, outputs));
    }
    PipelineGraph::new(nodes).expect("random chain is valid")
}

#[test]
fn prop_live_set_is_exactly_the_cut_edges() {
    check("liveness cut", default_cases(), |rng| {
        let g = random_chain_graph(rng);
        for sp in g.all_splits() {
            let live = g.live_set(sp);
            let head: std::collections::HashSet<&str> = g
                .head_nodes(sp)
                .iter()
                .flat_map(|n| n.outputs.iter().map(String::as_str))
                .chain([PRIMAL])
                .collect();
            let tail_needs: std::collections::HashSet<&str> = g
                .tail_nodes(sp)
                .iter()
                .flat_map(|n| n.inputs.iter().map(String::as_str))
                .collect();
            // 1. everything in the live set is produced in the head and
            //    consumed in the tail
            for t in &live {
                prop_assert!(head.contains(t.as_str()), "'{t}' not head-produced");
                prop_assert!(tail_needs.contains(t.as_str()), "'{t}' not tail-consumed");
            }
            // 2. completeness: every tail-consumed head-tensor is present
            for t in tail_needs {
                let produced_in_tail = g
                    .tail_nodes(sp)
                    .iter()
                    .any(|n| n.outputs.iter().any(|o| o == t));
                if head.contains(t) && !produced_in_tail {
                    prop_assert!(
                        live.iter().any(|l| l == t),
                        "live set missing '{t}' at split {sp:?}"
                    );
                }
            }
            // 3. no duplicates
            let mut dedup = live.clone();
            dedup.dedup();
            prop_assert!(dedup.len() == live.len(), "duplicate entries in live set");
        }
        Ok(())
    });
}

/// The pre-refactor string-keyed live-set algorithm, kept verbatim as the
/// reference semantics: first-seen tail-consumption order, then a stable
/// sort by producing node (primal first).
fn string_keyed_live_set(g: &PipelineGraph, head_len: usize) -> Vec<String> {
    let mut produced_by: std::collections::HashMap<&str, usize> = Default::default();
    for (i, n) in g.nodes().iter().enumerate() {
        for o in &n.outputs {
            produced_by.insert(o.as_str(), i);
        }
    }
    if head_len >= g.len() {
        return vec![];
    }
    let mut live: Vec<String> = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for tail in &g.nodes()[head_len..] {
        for inp in &tail.inputs {
            let produced_in_head = match produced_by.get(inp.as_str()) {
                None => true, // primal
                Some(&p) => p < head_len,
            };
            if produced_in_head && seen.insert(inp.clone()) {
                live.push(inp.clone());
            }
        }
    }
    live.sort_by_key(|t| produced_by.get(t.as_str()).map_or(-1, |&p| p as i64));
    live
}

#[test]
fn prop_interned_live_sets_match_string_keyed_semantics() {
    // the id-interned, build-time-precomputed live sets must reproduce the
    // stringly-typed per-frame computation exactly — names AND order
    check("interned == string-keyed", default_cases(), |rng| {
        let g = random_chain_graph(rng);
        for sp in g.all_splits() {
            let reference = string_keyed_live_set(&g, sp.head_len);
            prop_assert!(
                g.live_set(sp) == reference,
                "live_set diverged at {sp:?}: {:?} vs {reference:?}",
                g.live_set(sp)
            );
            let by_id: Vec<String> = g
                .live_ids(sp)
                .iter()
                .map(|&id| g.tensor_name(id).to_string())
                .collect();
            prop_assert!(
                by_id == reference,
                "live_ids diverged at {sp:?}: {by_id:?} vs {reference:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_id_store_packets_encode_byte_identical_to_owned() {
    use splitpoint::model::graph::TensorStore;
    use std::sync::Arc;
    // frame packets assembled from the Arc slot store must produce the
    // same bytes as the old deep-cloning string-keyed assembly
    check("store packet bytes", default_cases().min(24), |rng| {
        let g = random_chain_graph(rng);
        let mut store = TensorStore::for_graph(&g);
        let mut owned: Vec<(String, Tensor)> = Vec::new();
        for idx in 0..g.tensor_count() {
            let id = splitpoint::model::graph::TensorId(idx as u32);
            let occ = rng.f64();
            let t = random_tensor(rng, occ);
            owned.push((g.tensor_name(id).to_string(), t.clone()));
            store.insert(id, Arc::new(t));
        }
        for sp in g.all_splits() {
            let live = g.live_ids(sp);
            if live.is_empty() {
                continue;
            }
            let shared = Packet::from_shared(
                live.iter()
                    .map(|&id| {
                        (
                            g.tensor_name(id).to_string(),
                            store.get(id).cloned().unwrap(),
                        )
                    })
                    .collect(),
            );
            let cloned = Packet::new(
                g.live_set(sp)
                    .into_iter()
                    .map(|n| {
                        let t = owned.iter().find(|(on, _)| *on == n).unwrap().1.clone();
                        (n, t)
                    })
                    .collect(),
            );
            for policy in [Policy::Auto, Policy::Dense, Policy::AutoQuantized] {
                let a = shared.encode(policy);
                let b = cloned.encode(policy);
                prop_assert!(a == b, "bytes diverged at {sp:?} under {policy:?}");
                // a second encode runs off the now-cached site index and
                // must be byte-stable
                prop_assert!(
                    shared.encode(policy) == a,
                    "cached re-encode diverged at {sp:?} under {policy:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_codec_roundtrips_through_reused_buffer() {
    // one wire buffer reused across frames of wildly varying size/format
    let mut buf = Vec::new();
    check("encode_into reuse", default_cases(), |rng| {
        let occ_t = rng.f64();
        let occ_m = rng.f64();
        let t = random_tensor(rng, occ_t);
        let m = random_mask(rng, occ_m);
        let p = Packet::new(vec![("f".into(), t.clone()), ("m".into(), m.clone())]);
        let policy = *rng.pick(&[Policy::Auto, Policy::Dense, Policy::AutoQuantized]);
        p.encode_into(policy, &mut buf);
        prop_assert!(buf == p.encode(policy), "encode_into != encode ({policy:?})");
        let back = Packet::decode(&buf).map_err(|e| format!("decode: {e}"))?;
        if policy != Policy::AutoQuantized {
            prop_assert!(back.get("f") == Some(&t), "tensor mutated through reuse");
            prop_assert!(back.get("m") == Some(&m), "mask mutated through reuse");
        }
        Ok(())
    });
}

#[test]
fn prop_pooled_voxelizer_matches_fresh() {
    use splitpoint::pointcloud::{Point, PointCloud};
    use splitpoint::voxel::Voxelizer;

    let manifest_json = include_str!("data/test_manifest.json");
    let manifest =
        splitpoint::Manifest::parse(manifest_json, std::path::Path::new("/nonexistent")).unwrap();
    let pooled = Voxelizer::from_config(&manifest.config);

    check("pooled voxelizer", 16, |rng| {
        let cloud = PointCloud {
            points: (0..rng.range(0, 800) as usize)
                .map(|_| Point {
                    x: rng.uniform(-5.0, 50.0) as f32,
                    y: rng.uniform(-30.0, 30.0) as f32,
                    z: rng.uniform(-4.0, 2.0) as f32,
                    intensity: rng.f32(),
                })
                .collect(),
        };
        // `pooled` recycles its grids between cases; a fresh voxelizer
        // never sees a dirty buffer
        let fresh = Voxelizer::from_config(&manifest.config);
        let a = pooled.voxelize(&cloud);
        let b = fresh.voxelize(&cloud);
        prop_assert!(a.in_range == b.in_range, "in_range diverged");
        prop_assert!(*a.sum == *b.sum, "pooled sum grid diverged");
        prop_assert!(*a.cnt == *b.cnt, "pooled cnt grid diverged");
        prop_assert!(
            a.cnt.site_index() == b.cnt.site_index(),
            "occupied-site index diverged"
        );
        pooled.recycle(a);
        Ok(())
    });
}

#[test]
fn prop_edge_plus_tail_nodes_partition_graph() {
    check("split partition", default_cases(), |rng| {
        let g = random_chain_graph(rng);
        for sp in g.all_splits() {
            prop_assert!(
                g.head_nodes(sp).len() + g.tail_nodes(sp).len() == g.len(),
                "partition broken at {sp:?}"
            );
        }
        Ok(())
    });
}

// ------------------------------------------------------------------ json

#[test]
fn prop_json_roundtrip() {
    fn random_value(rng: &mut Rng, depth: usize) -> json::Value {
        use json::Value;
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => Value::Str(
                (0..rng.below(12))
                    .map(|_| *rng.pick(&['a', 'π', '"', '\\', '\n', 'z', ' ']))
                    .collect(),
            ),
            4 => Value::Arr((0..rng.below(5)).map(|_| random_value(rng, depth + 1)).collect()),
            _ => Value::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    check("json roundtrip", default_cases(), |rng| {
        let v = random_value(rng, 0);
        let compact = json::parse(&v.to_string()).map_err(|e| format!("compact: {e}"))?;
        prop_assert!(compact == v, "compact roundtrip mutated value");
        let pretty = json::parse(&v.pretty()).map_err(|e| format!("pretty: {e}"))?;
        prop_assert!(pretty == v, "pretty roundtrip mutated value");
        Ok(())
    });
}

// --------------------------------------------------------------- metrics

#[test]
fn prop_percentiles_are_order_statistics() {
    check("percentiles", default_cases(), |rng| {
        let n = rng.range(1, 200) as usize;
        let mut s = Stats::new();
        let mut xs: Vec<f64> = (0..n).map(|_| rng.normal() * 10.0).collect();
        for &x in &xs {
            s.push(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        prop_assert!((s.percentile(0.0) - xs[0]).abs() < 1e-9, "p0 != min");
        prop_assert!(
            (s.percentile(100.0) - xs[n - 1]).abs() < 1e-9,
            "p100 != max"
        );
        let p50 = s.percentile(50.0);
        prop_assert!(p50 >= xs[0] - 1e-9 && p50 <= xs[n - 1] + 1e-9, "p50 outside range");
        // monotone in q
        let mut prev = f64::NEG_INFINITY;
        for q in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let v = s.percentile(q);
            prop_assert!(v >= prev - 1e-12, "percentile not monotone at q={q}");
            prev = v;
        }
        Ok(())
    });
}

#[test]
fn prop_simtime_add_is_associative_enough() {
    check("simtime", default_cases(), |rng| {
        let a = SimTime::from_secs_f64(rng.f64());
        let b = SimTime::from_secs_f64(rng.f64());
        let c = SimTime::from_secs_f64(rng.f64());
        let l = (a + b) + c;
        let r = a + (b + c);
        prop_assert!(l == r, "associativity failed");
        prop_assert!(a + SimTime::ZERO == a, "identity failed");
        Ok(())
    });
}

// ------------------------------------------------------------- voxelizer

#[test]
fn prop_voxelizer_conserves_points() {
    use splitpoint::pointcloud::{Point, PointCloud};
    use splitpoint::voxel::Voxelizer;

    // a config matching the python-side geometry
    let manifest_json = include_str!("data/test_manifest.json");
    let manifest =
        splitpoint::Manifest::parse(manifest_json, std::path::Path::new("/nonexistent")).unwrap();
    let vox = Voxelizer::from_config(&manifest.config);

    check("voxelizer conserves", default_cases(), |rng| {
        let n = rng.range(0, 500) as usize;
        let points: Vec<Point> = (0..n)
            .map(|_| Point {
                // straddle the range boundary: ~half in range
                x: rng.uniform(-10.0, 56.0) as f32,
                y: rng.uniform(-33.0, 33.0) as f32,
                z: rng.uniform(-4.0, 2.0) as f32,
                intensity: rng.f32(),
            })
            .collect();
        let cloud = PointCloud { points };
        let g = vox.voxelize(&cloud);
        let total_cnt: f32 = g.cnt.data().iter().sum();
        prop_assert!(
            total_cnt as usize == g.in_range,
            "cnt sum {total_cnt} != in_range {}",
            g.in_range
        );
        prop_assert!(g.in_range <= cloud.len(), "more scattered than given");
        // feature sums are finite
        prop_assert!(
            g.sum.data().iter().all(|x| x.is_finite()),
            "non-finite sums"
        );
        Ok(())
    });
}

// ------------------------------------------------------------ robustness

#[test]
fn prop_packet_decode_survives_fuzz() {
    // arbitrary bytes must produce Err or Ok — never a panic/abort
    check("decode fuzz", default_cases(), |rng| {
        let n = rng.range(0, 300) as usize;
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        let _ = Packet::decode(&bytes); // outcome irrelevant; no panic
        Ok(())
    });
}

#[test]
fn prop_packet_truncation_always_errors() {
    check("truncation", default_cases(), |rng| {
        let occ = rng.f64();
        let t = random_tensor(rng, occ);
        let p = Packet::new(vec![("t".into(), t)]);
        let bytes = p.encode(Policy::Auto);
        if bytes.len() < 2 {
            return Ok(());
        }
        let cut = 1 + rng.below(bytes.len() - 1);
        prop_assert!(
            Packet::decode(&bytes[..cut]).is_err(),
            "truncated packet ({cut}/{}) decoded successfully",
            bytes.len()
        );
        Ok(())
    });
}

#[test]
fn prop_packet_bitflip_never_panics() {
    check("bitflip", default_cases(), |rng| {
        let occ_t = rng.f64();
        let occ_m = rng.f64();
        let t = random_tensor(rng, occ_t);
        let m = random_mask(rng, occ_m);
        let p = Packet::new(vec![("a".into(), t), ("b".into(), m)]);
        let mut bytes = p.encode(Policy::Auto);
        let i = rng.below(bytes.len());
        bytes[i] ^= 1 << rng.below(8);
        let _ = Packet::decode(&bytes); // no panic
        Ok(())
    });
}

#[test]
fn prop_cli_parser_never_panics() {
    use splitpoint::util::cli::{Cli, CommandSpec};
    let cli = Cli {
        bin: "t",
        about: "fuzz",
        commands: vec![CommandSpec {
            name: "run",
            help: "",
            opts: vec![],
        }],
        global_opts: vec![],
    };
    let tokens = [
        "run", "--x", "--y=1", "-z", "pos", "--", "--frames", "10", "=",
        "--=", "--a=b=c", "π", "",
    ];
    check("cli fuzz", default_cases(), |rng| {
        let n = rng.range(0, 6) as usize;
        let argv: Vec<String> = (0..n)
            .map(|_| tokens[rng.below(tokens.len())].to_string())
            .filter(|t| t != "-h" && t != "--help")
            .collect();
        let _ = cli.parse(&argv); // Err is fine; panic is not
        Ok(())
    });
}

#[test]
fn prop_nms_respects_max_keep_and_empty() {
    check("nms bounds", default_cases(), |rng| {
        let n = rng.range(0, 30) as usize;
        let mut dets: Vec<Detection> = (0..n)
            .map(|_| Detection {
                score: rng.f32(),
                boxx: random_box(rng),
                class: 0,
            })
            .collect();
        dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        let max_keep = rng.below(10);
        let keep = nms_bev(&dets, 0.5, max_keep);
        prop_assert!(keep.len() <= max_keep, "kept {} > {max_keep}", keep.len());
        prop_assert!(
            keep.len() <= dets.len(),
            "kept more than given"
        );
        Ok(())
    });
}

#[test]
fn prop_rng_fork_streams_are_independent() {
    check("rng fork", 16, |rng| {
        let mut a = rng.fork();
        let mut b = rng.fork();
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        prop_assert!(same < 4, "forked streams collide ({same}/32)");
        Ok(())
    });
}
