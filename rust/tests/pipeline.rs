//! Pipelined multi-frame execution invariants (require `make artifacts`).
//!
//! The contract under test: the staged pipeline is an *execution schedule*,
//! never a semantic change. At every depth and tail-worker count, pipelined
//! output must be byte-identical to the serial `run_frame` path — same
//! detections bit for bit, same wire byte counts — and frames must complete
//! in submission order. Shutdown must drain, never deadlock.

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use splitpoint::config::SystemConfig;
use splitpoint::coordinator::batcher::{BatchPolicy, Batcher};
use splitpoint::coordinator::pipeline::{run_stream, Pipeline, PipelineConfig};
use splitpoint::coordinator::remote::{EdgeClient, Server};
use splitpoint::coordinator::{Engine, FrameResult};
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::pointcloud::{Frame, PointCloud};
use splitpoint::postprocess::Detection;
use splitpoint::testing::{check, default_cases};
use splitpoint::util::rng::Rng;
use splitpoint::{prop_assert, Manifest};

fn engine() -> Arc<Engine> {
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            let manifest =
                Manifest::load(&dir).expect("run `make artifacts` before cargo test");
            Arc::new(Engine::new(&manifest, SystemConfig::paper()).expect("engine"))
        })
        .clone()
}

fn clouds(seed0: u64, n: usize) -> Vec<PointCloud> {
    (0..n)
        .map(|i| SceneGenerator::with_seed(seed0 + i as u64).generate().cloud)
        .collect()
}

/// Bit-exact detection equality — the pipeline may not perturb a single
/// mantissa bit relative to serial execution.
fn dets_bitwise_equal(a: &[Detection], b: &[Detection]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.class == y.class
                && x.score.to_bits() == y.score.to_bits()
                && x.boxx
                    .iter()
                    .zip(&y.boxx)
                    .all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

fn frames_identical(a: &FrameResult, b: &FrameResult) -> bool {
    dets_bitwise_equal(&a.detections, &b.detections)
        && a.timing.uplink_bytes == b.timing.uplink_bytes
        && a.timing.downlink_bytes == b.timing.downlink_bytes
        && a.timing.split_label == b.timing.split_label
        && a.timing.node_times.len() == b.timing.node_times.len()
}

#[test]
fn pipelined_equals_serial_at_depths_1_to_4() {
    let e = engine();
    let sp = e.graph().split_after("vfe").unwrap();
    let stream = clouds(500, 6);
    let serial: Vec<FrameResult> = stream
        .iter()
        .map(|c| e.run_frame(c, sp).unwrap())
        .collect();
    for depth in 1..=4usize {
        for tail_workers in [1, 2] {
            let (piped, report) = run_stream(
                e.clone(),
                sp,
                &stream,
                PipelineConfig {
                    depth,
                    tail_workers,
                },
            )
            .unwrap();
            assert_eq!(piped.len(), serial.len());
            for (i, (p, s)) in piped.iter().zip(&serial).enumerate() {
                assert!(
                    frames_identical(p, s),
                    "frame {i} diverged at depth {depth}, tail_workers {tail_workers}: \
                     {} vs {} dets",
                    p.detections.len(),
                    s.detections.len()
                );
            }
            assert_eq!(report.frames, stream.len());
            // every stage saw every frame
            for stage in ["stage/head", "stage/transfer", "stage/tail"] {
                assert_eq!(
                    report.stage_latency.get(stage).map(|s| s.count()),
                    Some(stream.len()),
                    "{stage} at depth {depth}"
                );
            }
        }
    }
}

#[test]
fn prop_pipelined_equals_serial_on_random_streams() {
    let e = engine();
    let splits = e.graph().all_splits();
    // full-frame property: keep the case count modest, the deterministic
    // depth sweep above covers the schedule matrix exhaustively
    let cases = default_cases().min(6).max(3);
    check("pipelined == serial", cases, |rng: &mut Rng| {
        let sp = *rng.pick(&splits);
        let n = rng.range(1, 3) as usize;
        let stream = clouds(1000 + rng.below(1000) as u64, n);
        let depth = rng.range(1, 4) as usize;
        let tail_workers = rng.range(1, 2) as usize;
        let (piped, _) = run_stream(
            e.clone(),
            sp,
            &stream,
            PipelineConfig {
                depth,
                tail_workers,
            },
        )
        .map_err(|err| format!("pipeline failed: {err:#}"))?;
        for (i, cloud) in stream.iter().enumerate() {
            let serial = e
                .run_frame(cloud, sp)
                .map_err(|err| format!("serial failed: {err:#}"))?;
            prop_assert!(
                frames_identical(&piped[i], &serial),
                "frame {i} diverged at split '{}' depth {depth} tails {tail_workers}",
                e.graph().split_label(sp)
            );
        }
        Ok(())
    });
}

#[test]
fn results_arrive_in_submission_order_with_parallel_tails() {
    let e = engine();
    let sp = e.graph().split_after("vfe").unwrap();
    let stream = clouds(700, 8);
    // serial references, one per distinct frame
    let serial: Vec<FrameResult> = stream
        .iter()
        .map(|c| e.run_frame(c, sp).unwrap())
        .collect();
    let pipeline = Pipeline::spawn(
        e.clone(),
        sp,
        PipelineConfig {
            depth: 3,
            tail_workers: 2,
        },
    )
    .unwrap();
    std::thread::scope(|s| {
        let p = &pipeline;
        let stream = &stream;
        s.spawn(move || {
            for (i, cloud) in stream.iter().enumerate() {
                let seq = p.submit(cloud.clone()).unwrap();
                assert_eq!(seq, i as u64, "sequence numbers are dense");
            }
            p.close();
        });
        // frame i's result must match frame i's serial run — out-of-order
        // delivery would pair result j with reference i and mismatch
        for reference in serial.iter() {
            let got = p.next_result().expect("stream ended early").unwrap();
            assert!(frames_identical(&got, reference), "out-of-order delivery");
        }
        assert!(p.next_result().is_none(), "drained pipeline yields None");
    });
    assert_eq!(pipeline.submitted(), stream.len() as u64);
}

#[test]
fn close_without_frames_drains_immediately() {
    let e = engine();
    let sp = e.graph().split_after("vfe").unwrap();
    for depth in 1..=4usize {
        let pipeline =
            Pipeline::spawn(e.clone(), sp, PipelineConfig::with_depth(depth)).unwrap();
        pipeline.close();
        assert!(pipeline.next_result().is_none(), "depth {depth}");
        assert!(pipeline.submit(PointCloud::default()).is_err());
    }
}

#[test]
fn queued_frames_drain_in_order_after_close_at_every_depth() {
    let e = engine();
    let sp = e.graph().split_after("conv1").unwrap();
    let stream = clouds(900, 4);
    let serial: Vec<FrameResult> = stream
        .iter()
        .map(|c| e.run_frame(c, sp).unwrap())
        .collect();
    for depth in 1..=4usize {
        let pipeline =
            Pipeline::spawn(e.clone(), sp, PipelineConfig::with_depth(depth)).unwrap();
        std::thread::scope(|s| {
            let p = &pipeline;
            let stream = &stream;
            s.spawn(move || {
                for cloud in stream.iter() {
                    p.submit(cloud.clone()).unwrap();
                }
                // close with frames still queued/in flight: they must all
                // drain — close is a "no more input" signal, not an abort
                p.close();
            });
            for (i, reference) in serial.iter().enumerate() {
                let got = p
                    .next_result()
                    .unwrap_or_else(|| panic!("depth {depth}: lost frame {i}"))
                    .unwrap();
                assert!(frames_identical(&got, reference), "depth {depth} frame {i}");
            }
            assert!(p.next_result().is_none());
        });
    }
}

#[test]
fn empty_cloud_flows_through_the_pipeline() {
    let e = engine();
    let sp = e.graph().split_after("vfe").unwrap();
    let stream = vec![PointCloud::default(), clouds(42, 1).remove(0)];
    let (results, _) = run_stream(e.clone(), sp, &stream, PipelineConfig::default()).unwrap();
    assert_eq!(results.len(), 2);
    let serial = e.run_frame(&stream[1], sp).unwrap();
    assert!(frames_identical(&results[1], &serial));
}

#[test]
fn batcher_feeds_the_pipeline_in_order() {
    let e = engine();
    let sp = e.graph().split_after("vfe").unwrap();
    let stream = clouds(800, 5);
    let serial: Vec<FrameResult> = stream
        .iter()
        .map(|c| e.run_frame(c, sp).unwrap())
        .collect();

    let batcher = Arc::new(Batcher::new(BatchPolicy {
        max_frames: 2,
        max_wait: Duration::from_millis(5),
    }));
    let pipeline =
        Pipeline::spawn(e.clone(), sp, PipelineConfig::with_depth(2)).unwrap();

    std::thread::scope(|s| {
        let p = &pipeline;
        let b = batcher.clone();
        let drain = s.spawn(move || b.drain_into_pipeline(p));
        for (seq, cloud) in stream.iter().enumerate() {
            batcher.push(Frame {
                sensor_id: 0,
                seq: seq as u64,
                cloud: cloud.clone(),
            });
        }
        batcher.close();
        let forwarded = drain.join().unwrap();
        assert_eq!(forwarded, stream.len());
        pipeline.close();
        for (i, reference) in serial.iter().enumerate() {
            let got = p.next_result().expect("lost frame").unwrap();
            assert!(frames_identical(&got, reference), "frame {i} out of order");
        }
        assert!(p.next_result().is_none());
    });
}

#[test]
fn tcp_pipelined_stream_matches_serial_client() {
    let e = engine();
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = Manifest::load(&dir).unwrap();
    let shared = Arc::new(
        Engine::with_runtime(&manifest, SystemConfig::paper(), e.runtime().clone()).unwrap(),
    );
    let server = Server::spawn("127.0.0.1:0", shared.clone()).unwrap();
    let addr = server.addr();
    let sp = shared.graph().split_after("vfe").unwrap();
    let stream = clouds(600, 5);

    // serial reference over its own connection
    let mut serial_client = EdgeClient::connect(addr, shared.clone()).unwrap();
    let serial: Vec<Vec<Detection>> = stream
        .iter()
        .map(|c| serial_client.run_frame(c, sp).unwrap().0)
        .collect();
    serial_client.shutdown().unwrap();

    // pipelined stream at depth 3: same detections, same order
    let mut client = EdgeClient::connect(addr, shared.clone()).unwrap();
    let results = client.run_stream(&stream, sp, 3).unwrap();
    assert_eq!(results.len(), stream.len());
    for (i, ((dets, timing), reference)) in results.iter().zip(&serial).enumerate() {
        assert!(
            dets_bitwise_equal(dets, reference),
            "frame {i} diverged over the pipelined socket"
        );
        assert!(timing.uplink_bytes > 0);
        assert!(timing.inference_time.nanos > 0);
    }
    // depth 1 degenerates to the serial loop
    let one = client.run_stream(&stream[..2], sp, 1).unwrap();
    assert!(dets_bitwise_equal(&one[0].0, &serial[0]));
    client.shutdown().unwrap();
    server.shutdown().unwrap();
}
