//! Wire codec v3 (lossy uplink precisions) end-to-end invariants
//! (require `make artifacts`).
//!
//! The contract under test, in three parts:
//!   1. `--wire f32` is a no-op — byte-identical v2 frames, bitwise-
//!      identical detections, zero v3 accounting.
//!   2. `--wire f16|int8` changes detections only within the comparator's
//!      tolerances, ships measurably fewer bytes, and fills the v3
//!      accounting (`uplink_v3_bytes`, `quant_savings`).
//!   3. Quantization is transport-invariant: the TCP path and the
//!      in-process path dequantize to bitwise-identical detections, so
//!      retransmitted quantized frames dedup cleanly (fault-matrix lane).

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use splitpoint::coordinator::session::{ServerSession, SessionFrame, SplitSession};
use splitpoint::coordinator::Engine;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::pointcloud::{PointCloud, ReplaySource};
use splitpoint::postprocess::compare::{self, FrameDets, Tolerance};
use splitpoint::postprocess::Detection;
use splitpoint::tensor::codec::WirePrecision;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Shared baseline (f32) engine for the whole binary.
fn engine() -> Arc<Engine> {
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            SplitSession::builder()
                .artifacts(artifacts_dir())
                .build_engine()
                .expect("engine")
        })
        .clone()
}

fn clouds(seed0: u64, n: usize) -> Vec<PointCloud> {
    (0..n)
        .map(|i| SceneGenerator::with_seed(seed0 + i as u64).generate().cloud)
        .collect()
}

fn dets_bitwise_equal(a: &[Detection], b: &[Detection]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.class == y.class
                && x.score.to_bits() == y.score.to_bits()
                && x.boxx
                    .iter()
                    .zip(&y.boxx)
                    .all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

/// Session frames → comparator frames.
fn to_frames(frames: &[SessionFrame]) -> Vec<FrameDets> {
    frames
        .iter()
        .map(|f| FrameDets {
            seq: f.seq,
            sensor: f.sensor_id,
            source_seq: f.source_seq,
            points: f.points,
            dets: f.output.detections.clone(),
        })
        .collect()
}

/// One in-process session at the given precision over `stream`.
fn run_at(
    precision: WirePrecision,
    stream: &[PointCloud],
) -> (Vec<SessionFrame>, splitpoint::coordinator::session::SessionReport) {
    // wire_precision overrides engine *config*, so the session builds its
    // own engine from artifacts instead of borrowing the shared one
    let mut session = SplitSession::builder()
        .artifacts(artifacts_dir())
        .wire_precision(precision)
        .source(Box::new(ReplaySource::from_clouds(stream.to_vec())))
        .build()
        .unwrap();
    session.run().unwrap()
}

/// `--wire f32` must be invisible: bitwise-identical detections to the
/// default engine, identical uplink byte counts, and no v3 accounting.
#[test]
fn f32_wire_is_bitwise_identical_with_no_v3_accounting() {
    let e = engine();
    let stream = clouds(31000, 3);
    let (frames, report) = run_at(WirePrecision::F32, &stream);
    assert_eq!(frames.len(), stream.len());
    for f in &frames {
        let serial = e.run_frame(&stream[f.source_seq as usize], f.split).unwrap();
        assert!(
            dets_bitwise_equal(&f.output.detections, &serial.detections),
            "frame {}: --wire f32 changed detections",
            f.seq
        );
        assert_eq!(f.output.uplink_bytes, serial.timing.uplink_bytes);
        assert_eq!(f.output.uplink_v3_bytes, 0, "f32 ships v2 frames");
        // the f32 twin of an f32 run is the run itself
        assert_eq!(f.output.uplink_f32_bytes, f.output.uplink_bytes);
    }
    assert_eq!(report.uplink_v3_bytes, 0);
    assert!(report.quant_savings().is_none());
    assert!(report.summary().contains("wire v2"), "{}", report.summary());
}

/// f16 and int8 sessions pass the tolerance comparator against the f32
/// baseline, ship strictly fewer uplink bytes, and report the savings.
#[test]
fn quantized_sessions_pass_comparator_and_save_bytes() {
    let stream = clouds(32000, 3);
    let (base_frames, base_report) = run_at(WirePrecision::F32, &stream);
    let baseline = to_frames(&base_frames);
    assert!(base_report.uplink_bytes > 0, "test needs a non-empty live set");

    for precision in [WirePrecision::F16, WirePrecision::Int8] {
        let (frames, report) = run_at(precision, &stream);
        let r = compare::compare_runs(&baseline, &to_frames(&frames), &Tolerance::default())
            .unwrap();
        assert!(
            r.pass(),
            "--wire {} drifted beyond tolerance: {}",
            precision.as_str(),
            r.summary()
        );

        assert!(
            report.uplink_v3_bytes > 0,
            "--wire {} must account shipped v3 bytes",
            precision.as_str()
        );
        assert_eq!(report.uplink_v3_bytes, report.uplink_bytes);
        assert!(
            report.uplink_bytes < report.uplink_f32_bytes,
            "--wire {} shipped {} bytes but f32 twin is {}",
            precision.as_str(),
            report.uplink_bytes,
            report.uplink_f32_bytes
        );
        let savings = report.quant_savings().expect("quantized run reports savings");
        assert!(savings > 0.0 && savings < 1.0, "savings {savings}");
        assert!(
            report.summary().contains("wire v3 quantized"),
            "{}",
            report.summary()
        );
        // int8 payloads are half of f16's — savings must be ordered
        if precision == WirePrecision::Int8 {
            let f16_report = run_at(WirePrecision::F16, &stream).1;
            assert!(report.uplink_bytes < f16_report.uplink_bytes);
        }
    }
}

/// Transport invariance under quantization: an int8 TCP session is
/// bitwise-identical to the in-process int8 session — the dequantized
/// tensors, and hence the tail numerics, do not depend on the transport.
/// This is what makes retransmitted quantized frames dedup bit-exactly
/// in the fault-matrix lane.
#[test]
fn quantized_tcp_matches_in_process_bitwise() {
    let stream = clouds(33000, 2);
    let (local_frames, _) = run_at(WirePrecision::Int8, &stream);

    let server = ServerSession::builder()
        .listen("127.0.0.1:0")
        .artifacts(artifacts_dir())
        .build()
        .unwrap();
    let addr = server.addr().to_string();
    let mut session = SplitSession::builder()
        .artifacts(artifacts_dir())
        .wire_precision(WirePrecision::Int8)
        .source(Box::new(ReplaySource::from_clouds(stream.clone())))
        .tcp(&addr)
        .build()
        .unwrap();
    let (tcp_frames, report) = session.run().unwrap();
    assert_eq!(tcp_frames.len(), local_frames.len());
    for (a, b) in local_frames.iter().zip(&tcp_frames) {
        assert!(
            dets_bitwise_equal(&a.output.detections, &b.output.detections),
            "frame {}: quantized detections depend on the transport",
            a.seq
        );
    }
    assert!(report.uplink_v3_bytes > 0, "TCP path fills the v3 accounting");
    assert!(report.quant_savings().is_some());
    server.shutdown().unwrap();
}
