//! Concurrent multi-client server invariants (require `make artifacts`).
//!
//! The contract under test: the shared-tail session server is pure
//! *scheduling*. However many clients connect, whatever splits and
//! pipeline depths they mix, and however their frames coalesce into
//! cross-session tail batches, every client's detections are byte-identical
//! to a solo `Engine::run_frame` run. Around that core: admission control
//! refuses (and recovers) instead of queueing unboundedly, graceful drain
//! flushes every admitted frame, and one misbehaving client never affects
//! the others.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use splitpoint::coordinator::fault::{ChaosProxy, DisconnectSpec, FaultProfile, RetryPolicy};
use splitpoint::coordinator::remote::{fetch_stats, ClientOptions, EdgeClient};
use splitpoint::coordinator::session::{ServerSession, SplitSession};
use splitpoint::coordinator::shutdown::{Shutdown, ShutdownMode};
use splitpoint::coordinator::transport::{read_message, write_message, Message};
use splitpoint::coordinator::Engine;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::pointcloud::PointCloud;
use splitpoint::postprocess::Detection;
use splitpoint::tensor::codec::Packet;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// One shared full engine for the whole test binary (runs both halves:
/// the server reuses it as its tail, each client as its head).
fn engine() -> Arc<Engine> {
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            SplitSession::builder()
                .artifacts(artifacts_dir())
                .build_engine()
                .expect("run `make artifacts` before cargo test")
        })
        .clone()
}

fn clouds(seed0: u64, n: usize) -> Vec<PointCloud> {
    (0..n)
        .map(|i| SceneGenerator::with_seed(seed0 + i as u64).generate().cloud)
        .collect()
}

fn dets_bitwise_equal(a: &[Detection], b: &[Detection]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.class == y.class
                && x.score.to_bits() == y.score.to_bits()
                && x.boxx
                    .iter()
                    .zip(y.boxx.iter())
                    .all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

/// Poll until `cond` holds (the server's counters are updated by its own
/// threads, so tests gate on observed state rather than sleeps).
fn wait_for(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "timed out waiting for {what}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// The tentpole contract: 8 concurrent clients on one server, mixing
/// splits and pipeline depths, every frame byte-identical to a solo run —
/// and the shared batcher demonstrably coalesced frames across sessions
/// while doing it.
#[test]
fn eight_concurrent_clients_match_solo_bitwise() {
    let full = engine();
    let server = ServerSession::builder()
        .listen("127.0.0.1:0")
        .engine(full.clone())
        // a small wait widens the coalescing window so the cross-session
        // batch assertion below is robust, without changing any output
        .batch(8, Duration::from_millis(2))
        .build()
        .unwrap();
    let addr = server.addr();

    let splits = ["vfe", "conv2", "bev_head", "edge_only"];
    let handles: Vec<_> = (0..8)
        .map(|i| {
            let full = full.clone();
            let split = splits[i % splits.len()];
            let depth = 1 + i % 3;
            std::thread::spawn(move || {
                let sp = full.graph().split_by_name(split).unwrap();
                let scenes = clouds(21_000 + 100 * i as u64, 3);
                let solo: Vec<Vec<Detection>> = scenes
                    .iter()
                    .map(|c| full.run_frame(c, sp).unwrap().detections)
                    .collect();
                let mut client = EdgeClient::connect(addr, full.clone()).unwrap();
                let results = client.run_stream(&scenes, sp, depth).unwrap();
                client.shutdown().unwrap();
                for (j, ((dets, _), solo)) in results.iter().zip(&solo).enumerate() {
                    assert!(
                        dets_bitwise_equal(dets, solo),
                        "client {i} ({split}, depth {depth}) frame {j} diverged under \
                         cross-client batching"
                    );
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let stats = server.stats();
    assert_eq!(stats.frames, 24, "8 clients x 3 frames");
    assert_eq!(stats.sessions_total, 8);
    assert_eq!(stats.session_errors, 0);
    assert!(stats.tail_batches >= 1);
    assert!(
        stats.multi_session_batches > 0,
        "no tail batch ever mixed sessions — cross-client coalescing is dead \
         (tail_batches={}, queue_max={})",
        stats.tail_batches,
        stats.queue_max
    );
    assert!(stats.uplink_bytes > 0 && stats.downlink_bytes > 0);
    server.shutdown().unwrap();
}

/// Admission control: at the pending cap the server answers `Busy` with a
/// retry hint instead of queueing, and serves the same client normally
/// once the backlog clears.
#[test]
fn pending_cap_refuses_with_busy_then_recovers() {
    let full = engine();
    let server = ServerSession::builder()
        .listen("127.0.0.1:0")
        .engine(full.clone())
        .pending_cap(1)
        // a long deadline (never hit max_frames) holds the first job in
        // the queue, pinning `pending` at the cap while B knocks
        .batch(64, Duration::from_millis(300))
        .build()
        .unwrap();

    // edge_only head: the tail runs zero nodes, so a raw-protocol client
    // can ship an empty live set without computing anything
    let head_len = full.graph().len() as u8;
    let empty = Packet::from_shared(Vec::new()).encode(full.config().codec);

    let mut a = TcpStream::connect(server.addr()).unwrap();
    let mut b = TcpStream::connect(server.addr()).unwrap();
    write_message(
        &mut a,
        &Message::Infer {
            request_id: 1,
            head_len,
            packet: empty.clone(),
        },
    )
    .unwrap();
    wait_for("frame A admitted", || server.stats().pending == 1);

    write_message(
        &mut b,
        &Message::Infer {
            request_id: 2,
            head_len,
            packet: empty.clone(),
        },
    )
    .unwrap();
    match read_message(&mut b).unwrap() {
        Message::Busy {
            request_id,
            pending,
        } => {
            assert_eq!(request_id, 2);
            assert!(pending >= 1, "retry hint carries the queue depth");
        }
        other => panic!("expected Busy at the pending cap, got {other:?}"),
    }

    // A's reply lands once the batch deadline fires
    match read_message(&mut a).unwrap() {
        Message::InferResult { request_id, .. } => assert_eq!(request_id, 1),
        other => panic!("expected A's result, got {other:?}"),
    }

    // recovery: with the backlog cleared, B's resubmission is served
    wait_for("backlog cleared", || server.stats().pending == 0);
    write_message(
        &mut b,
        &Message::Infer {
            request_id: 3,
            head_len,
            packet: empty,
        },
    )
    .unwrap();
    match read_message(&mut b).unwrap() {
        Message::InferResult { request_id, .. } => assert_eq!(request_id, 3),
        other => panic!("expected B's result after recovery, got {other:?}"),
    }

    let stats = server.stats();
    assert!(stats.busy_rejections >= 1);
    assert_eq!(stats.frames, 2, "refused requests must not be executed");
    write_message(&mut a, &Message::Shutdown).unwrap();
    write_message(&mut b, &Message::Shutdown).unwrap();
    server.shutdown().unwrap();
}

/// Graceful drain under load: shutdown with a full in-flight window still
/// delivers every admitted frame (zero dropped), bitwise-correct, within
/// the drain deadline.
#[test]
fn graceful_drain_flushes_every_admitted_frame() {
    let full = engine();
    let server = ServerSession::builder()
        .listen("127.0.0.1:0")
        .engine(full.clone())
        .drain_timeout(Duration::from_secs(30))
        .build()
        .unwrap();
    let sp = full.graph().split_by_name("vfe").unwrap();
    let scenes = clouds(23_000, 8);
    let solo: Vec<Vec<Detection>> = scenes
        .iter()
        .map(|c| full.run_frame(c, sp).unwrap().detections)
        .collect();

    let client = EdgeClient::connect(server.addr(), full.clone()).unwrap();
    let mut stream = client.into_stream(8).unwrap();
    for c in &scenes {
        stream.submit(c.clone(), sp).unwrap();
    }
    // once all 8 are *admitted* (exact count, read under the server's
    // window lock) shut down mid-flight: drain must flush them all
    wait_for("all 8 frames admitted", || {
        server
            .stats()
            .per_session
            .iter()
            .map(|s| s.submitted)
            .sum::<u64>()
            >= 8
    });
    let t0 = Instant::now();
    let shutdown = std::thread::spawn(move || server.shutdown());
    for (i, solo) in solo.iter().enumerate() {
        let (dets, _) = stream
            .recv()
            .unwrap_or_else(|e| panic!("frame {i} dropped during drain: {e:#}"));
        assert!(dets_bitwise_equal(&dets, solo), "frame {i} diverged");
    }
    shutdown.join().unwrap().expect("drain completed cleanly");
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "drain blew the deadline"
    );
    drop(stream); // server is gone; Drop's abort path handles the socket
}

/// Fault isolation: a client speaking garbage, one vanishing mid-frame,
/// and one aborting with work in flight are each logged and dropped —
/// the accept loop, shared batcher, and a healthy concurrent session all
/// keep working, bitwise.
#[test]
fn misbehaving_clients_are_isolated() {
    let full = engine();
    let server = ServerSession::builder()
        .listen("127.0.0.1:0")
        .engine(full.clone())
        .build()
        .unwrap();
    let sp = full.graph().split_by_name("vfe").unwrap();
    let scene = SceneGenerator::with_seed(24_500).generate();
    let solo = full.run_frame(&scene.cloud, sp).unwrap().detections;

    let mut good = EdgeClient::connect(server.addr(), full.clone()).unwrap();
    let (dets, _) = good.run_frame(&scene.cloud, sp).unwrap();
    assert!(dets_bitwise_equal(&dets, &solo));

    // (1) garbage: a frame header with the wrong magic
    let mut evil = TcpStream::connect(server.addr()).unwrap();
    evil.write_all(&[0xde, 0xad, 0xbe, 0xef, 1, 0, 0, 0, 0]).unwrap();
    evil.flush().unwrap();
    // the server logs the error and closes our session; nothing comes back
    let mut probe = [0u8; 1];
    let got = match evil.read(&mut probe) {
        Ok(n) => n,
        Err(_) => 0, // reset is as good as EOF here
    };
    assert_eq!(got, 0, "malformed session must be closed, not answered");

    // (2) mid-frame disconnect: half a header, then gone
    let mut cut = TcpStream::connect(server.addr()).unwrap();
    cut.write_all(&0x5350_4652u32.to_le_bytes()).unwrap();
    cut.flush().unwrap();
    drop(cut);

    // (3) abort with a frame in flight: the admitted job completes
    // server-side against a dead socket
    let aborting = EdgeClient::connect(server.addr(), full.clone()).unwrap();
    let mut aborting = aborting.into_stream(2).unwrap();
    aborting.submit(scene.cloud.clone(), sp).unwrap();
    aborting.shutdown_mode(ShutdownMode::Abort).unwrap();

    wait_for("misbehaving sessions reaped", || {
        server.stats().sessions_active == 1
    });
    assert!(
        server.stats().session_errors >= 1,
        "the malformed frame must surface as an isolated session error"
    );

    // the healthy session is unaffected — same bytes as before the chaos
    let (dets, _) = good.run_frame(&scene.cloud, sp).unwrap();
    assert!(
        dets_bitwise_equal(&dets, &solo),
        "healthy client diverged after other sessions misbehaved"
    );
    good.shutdown().unwrap();
    server.shutdown().unwrap();
}

/// The session cap refuses surplus connections with an actionable error
/// and accepts again once a slot frees.
#[test]
fn session_cap_refuses_then_recovers() {
    let full = engine();
    let server = ServerSession::builder()
        .listen("127.0.0.1:0")
        .engine(full.clone())
        .max_sessions(1)
        .build()
        .unwrap();

    let hold = TcpStream::connect(server.addr()).unwrap();
    wait_for("first session registered", || {
        server.stats().sessions_active == 1
    });

    let mut refused = TcpStream::connect(server.addr()).unwrap();
    match read_message(&mut refused) {
        Ok(Message::Error { message, .. }) => {
            assert!(message.contains("capacity"), "got: {message}")
        }
        other => panic!("expected a capacity refusal, got {other:?}"),
    }
    assert!(server.stats().accept_refusals >= 1);

    drop(hold);
    wait_for("slot freed", || server.stats().sessions_active == 0);
    let scene = SceneGenerator::with_seed(26_000).generate();
    let sp = full.graph().split_by_name("conv2").unwrap();
    let solo = full.run_frame(&scene.cloud, sp).unwrap().detections;
    let mut client = EdgeClient::connect(server.addr(), full.clone()).unwrap();
    let (dets, _) = client.run_frame(&scene.cloud, sp).unwrap();
    assert!(dets_bitwise_equal(&dets, &solo));
    client.shutdown().unwrap();
    server.shutdown().unwrap();
}

/// The `Stats` protocol request and the in-process snapshot agree, and
/// both carry the batching counters the soak lane greps for.
#[test]
fn stats_snapshot_in_process_and_over_the_wire() {
    let full = engine();
    let server = ServerSession::builder()
        .listen("127.0.0.1:0")
        .engine(full.clone())
        .build()
        .unwrap();
    let scene = SceneGenerator::with_seed(27_000).generate();
    let sp = full.graph().split_by_name("conv2").unwrap();
    let mut client = EdgeClient::connect(server.addr(), full.clone()).unwrap();
    let _ = client.run_frame(&scene.cloud, sp).unwrap();

    let text = fetch_stats(server.addr()).unwrap();
    assert!(text.contains("frames=1\n"), "wire snapshot:\n{text}");
    assert!(text.contains("tail_batches="));
    assert!(text.contains("multi_session_batches="));
    assert!(text.contains("session id="), "per-session rows present");

    let stats = server.stats();
    assert_eq!(stats.frames, 1);
    assert!(stats.tail_batches >= 1);
    assert_eq!(stats.multi_session_batches, 0, "one client, no coalescing");
    assert!(stats.uplink_bytes > 0 && stats.downlink_bytes > 0);
    assert!(stats.summary().contains("1 frame(s)"));

    client.shutdown().unwrap();
    server.shutdown().unwrap();
}

/// `Busy` is no longer fatal: the default client maps it to bounded
/// backoff and succeeds once the backlog clears, bitwise-identical to a
/// solo run, while the server's refusal counters still record the event.
#[test]
fn busy_auto_retry_succeeds_after_backoff() {
    let full = engine();
    let server = ServerSession::builder()
        .listen("127.0.0.1:0")
        .engine(full.clone())
        .pending_cap(1)
        // hold the pinned job long enough that the client's first attempt
        // sees Busy, short enough that its retry budget comfortably wins
        .batch(64, Duration::from_millis(150))
        .build()
        .unwrap();

    // pin the queue with a raw edge_only frame (empty live set)
    let head_len = full.graph().len() as u8;
    let empty = Packet::from_shared(Vec::new()).encode(full.config().codec);
    let mut pin = TcpStream::connect(server.addr()).unwrap();
    write_message(
        &mut pin,
        &Message::Infer {
            request_id: 1,
            head_len,
            packet: empty,
        },
    )
    .unwrap();
    wait_for("pinned frame admitted", || server.stats().pending == 1);

    let scene = SceneGenerator::with_seed(28_000).generate();
    let sp = full.graph().split_by_name("vfe").unwrap();
    let solo = full.run_frame(&scene.cloud, sp).unwrap().detections;

    let mut client = EdgeClient::connect(server.addr(), full.clone()).unwrap();
    let (dets, _) = client.run_frame(&scene.cloud, sp).unwrap();
    assert!(dets_bitwise_equal(&dets, &solo), "retried frame diverged");
    assert!(
        server.stats().busy_rejections >= 1,
        "the client was never refused — the retry path went unexercised"
    );
    assert!(
        client.counters().health().retries >= 1,
        "client telemetry missed the retry"
    );

    client.shutdown().unwrap();
    match read_message(&mut pin).unwrap() {
        Message::InferResult { request_id, .. } => assert_eq!(request_id, 1),
        other => panic!("expected the pinned frame's result, got {other:?}"),
    }
    write_message(&mut pin, &Message::Shutdown).unwrap();
    server.shutdown().unwrap();
}

/// The tentpole resilience contract: a resumable pipelined stream through
/// a link that hard-cuts mid-frame delivers every frame exactly once —
/// zero lost, zero duplicated executions, detections bitwise identical to
/// a solo run.
#[test]
fn reconnect_resume_no_loss_no_dup() {
    let full = engine();
    let server = ServerSession::builder()
        .listen("127.0.0.1:0")
        .engine(full.clone())
        .build()
        .unwrap();
    // cut every connection after an escalating byte budget: the first cut
    // lands inside the first vfe uplink, and the doubling budget
    // guarantees forward progress within the client's retry allowance
    let profile = FaultProfile {
        disconnect: Some(DisconnectSpec {
            first_bytes: 256 * 1024,
        }),
        ..FaultProfile::disconnect()
    };
    let proxy = ChaosProxy::spawn("127.0.0.1:0", server.addr(), profile, 7).unwrap();

    let sp = full.graph().split_by_name("vfe").unwrap();
    let scenes = clouds(29_000, 10);
    let solo: Vec<Vec<Detection>> = scenes
        .iter()
        .map(|c| full.run_frame(c, sp).unwrap().detections)
        .collect();

    let opts = ClientOptions {
        retry: RetryPolicy {
            max_retries: 12,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(200),
            seed: 9,
        },
        resume: true,
    };
    let client = EdgeClient::connect_with(proxy.addr(), full.clone(), opts).unwrap();
    let mut stream = client.into_stream(3).unwrap();
    let mut next = 0usize;
    for (i, solo) in solo.iter().enumerate() {
        while next < scenes.len() && next < i + 3 {
            stream.submit(scenes[next].clone(), sp).unwrap();
            next += 1;
        }
        let (dets, _) = stream
            .recv()
            .unwrap_or_else(|e| panic!("frame {i} lost across reconnects: {e:#}"));
        assert!(
            dets_bitwise_equal(&dets, solo),
            "frame {i} diverged across session resume"
        );
    }

    assert!(
        proxy.connections() >= 2,
        "the proxy never cut the link — resilience went unexercised"
    );
    assert!(
        stream.counters().health().reconnects >= 1,
        "client telemetry missed the reconnect"
    );
    let stats = server.stats();
    assert_eq!(
        stats.frames,
        scenes.len() as u64,
        "a frame was executed twice (retransmit dedup failed) or dropped"
    );
    assert!(stats.sessions_resumed >= 1, "no resume ever happened");
    assert_eq!(
        stats.session_errors, 0,
        "link cuts on a resumable session must park, not error"
    );
    let text = fetch_stats(server.addr()).unwrap();
    assert!(
        text.contains("sessions_resumed="),
        "wire snapshot misses the resume counter:\n{text}"
    );
    stream.shutdown().unwrap();
    drop(proxy);
    server.shutdown().unwrap();
}

/// Satellite (PR 9): sustained load across resumes keeps the server-side
/// resume ledger bounded. With the cap lowered to 8 and 24 frames driven
/// through a link that hard-cuts repeatedly, delivery stays exactly-once
/// and no snapshot ever shows a ledger above the cap — and the `/metrics`
/// HTTP endpoint (the `--metrics-addr` surface) serves the same counters
/// in Prometheus text while the run is still warm.
#[test]
fn resume_ledger_stays_bounded_under_sustained_load() {
    let full = engine();
    let cap = 8usize;
    let server = ServerSession::builder()
        .listen("127.0.0.1:0")
        .engine(full.clone())
        .resume_ledger_cap(cap)
        .metrics_addr("127.0.0.1:0")
        .build()
        .unwrap();
    let profile = FaultProfile {
        disconnect: Some(DisconnectSpec {
            first_bytes: 256 * 1024,
        }),
        ..FaultProfile::disconnect()
    };
    let proxy = ChaosProxy::spawn("127.0.0.1:0", server.addr(), profile, 11).unwrap();

    let sp = full.graph().split_by_name("vfe").unwrap();
    let scenes = clouds(31_000, 24);
    // detections are transport-invariant (pinned exhaustively elsewhere);
    // sampling a few here keeps the sustained-load loop fast
    let sampled: Vec<(usize, Vec<Detection>)> = [0usize, 11, 23]
        .iter()
        .map(|&i| (i, full.run_frame(&scenes[i], sp).unwrap().detections))
        .collect();

    let opts = ClientOptions {
        retry: RetryPolicy {
            max_retries: 12,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(200),
            seed: 5,
        },
        resume: true,
    };
    let client = EdgeClient::connect_with(proxy.addr(), full.clone(), opts).unwrap();
    let mut stream = client.into_stream(3).unwrap();
    let mut next = 0usize;
    let mut max_ledger = 0usize;
    for i in 0..scenes.len() {
        while next < scenes.len() && next < i + 3 {
            stream.submit(scenes[next].clone(), sp).unwrap();
            next += 1;
        }
        let (dets, _) = stream
            .recv()
            .unwrap_or_else(|e| panic!("frame {i} lost under sustained load: {e:#}"));
        if let Some((_, solo)) = sampled.iter().find(|(j, _)| *j == i) {
            assert!(dets_bitwise_equal(&dets, solo), "frame {i} diverged");
        }
        // the bound holds at every observation point, not just at the end
        for s in &server.stats().per_session {
            max_ledger = max_ledger.max(s.ledger);
            assert!(
                s.ledger <= cap,
                "frame {i}: ledger {} above cap {cap}",
                s.ledger
            );
        }
    }
    assert!(
        max_ledger >= cap,
        "ledger peaked at {max_ledger} < cap {cap} — the eviction path went unexercised"
    );

    let stats = server.stats();
    assert_eq!(stats.frames, scenes.len() as u64, "exactly-once delivery");
    assert!(stats.sessions_resumed >= 1, "no resume ever happened");
    assert_eq!(stats.session_errors, 0);

    // the HTTP exporter serves the same registry, Prometheus-shaped
    let addr = server.metrics_addr().expect("metrics endpoint enabled");
    let text = splitpoint::telemetry::scrape(addr).unwrap();
    assert!(
        text.contains("# TYPE sp_server_frames_total counter"),
        "scrape:\n{text}"
    );
    assert!(
        text.contains(&format!("sp_server_frames_total {}", scenes.len())),
        "scrape:\n{text}"
    );
    assert!(text.contains("sp_server_sessions_resumed_total"));
    assert!(text.contains("sp_stage_latency_seconds_bucket"));

    stream.shutdown().unwrap();
    drop(proxy);
    server.shutdown().unwrap();
}

/// Dropping the server with live sessions and in-flight work must abort
/// cleanly: no panic, no hang (the `Drop`-path half of the Shutdown
/// contract).
#[test]
fn server_drop_with_live_sessions_aborts_cleanly() {
    let full = engine();
    let server = ServerSession::builder()
        .listen("127.0.0.1:0")
        .engine(full.clone())
        .build()
        .unwrap();
    let _hold = TcpStream::connect(server.addr()).unwrap();
    wait_for("session registered", || server.stats().sessions_active == 1);
    drop(server);
}
