//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this path dependency
//! provides the subset of `anyhow`'s API the workspace actually uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` macros. Errors are a chain of strings — no
//! downcasting, no backtraces — which is all the coordinator needs for its
//! diagnostics. Swapping back to the real crate is a one-line Cargo change;
//! no call sites would have to move.

use std::fmt;

/// A string-chained error. Display prints the outermost message; the
/// alternate form (`{:#}`) and Debug print the whole chain separated by
/// `": "`, matching how `anyhow` renders context chains inline.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            msg: msg.to_string(),
            source: None,
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }

    fn fmt_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.fmt_chain(f)
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.fmt_chain(f)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that keeps the blanket `From` below coherent (same trick as `anyhow`).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // flatten the std error chain into our string chain
        let mut msgs = vec![e.to_string()];
        let mut cur = e.source();
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        let mut err = Error::msg(msgs.pop().expect("at least one message"));
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        err
    }
}

/// `anyhow::Result<T>` with the same default error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, like `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(context).context_under(e))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(f()).context_under(e))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

impl Error {
    /// Attach `cause` (any displayable error) underneath `self`.
    fn context_under<E: fmt::Display>(self, cause: E) -> Error {
        Error {
            msg: self.msg,
            // `{:#}` preserves the full chain when `cause` is an `Error`
            source: Some(Box::new(Error::msg(format!("{cause:#}")))),
        }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(format!("{e}").contains("disk on fire"));
    }

    #[test]
    fn context_chains_render_alternate() {
        let base: Result<(), String> = Err("inner".to_string());
        let e = base.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(format!("{e:?}"), "outer: inner");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        let m = anyhow!("x = {}", 42);
        assert_eq!(format!("{m}"), "x = 42");
        fn bails() -> Result<()> {
            bail!("boom {}", 1);
        }
        assert!(bails().is_err());
    }

    #[test]
    fn chain_lists_outermost_first() {
        let e = Error::msg("a").context("b").context("c");
        assert_eq!(e.chain(), ["c", "b", "a"]);
    }
}
