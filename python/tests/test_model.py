"""L2 correctness: module shapes, sparse-occupancy semantics, and the
pallas-vs-ref path equivalence over the whole pipeline."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import config as cfg
from compile import model


@pytest.fixture(scope="module")
def weights():
    return model.init_weights()


@pytest.fixture(scope="module")
def frame():
    """Synthetic voxelized frame: clustered occupancy like a LiDAR scene."""
    rng = np.random.default_rng(7)
    d, h, w = cfg.grid_shape()
    cnt = np.zeros((d, h, w, 1), np.float32)
    summ = np.zeros((d, h, w, cfg.POINT_FEATURES), np.float32)
    # ground-plane band + a few object clusters
    for _ in range(40):
        cz = rng.integers(0, 4)
        cy, cx = rng.integers(8, h - 8), rng.integers(8, w - 8)
        sz, sy, sx = rng.integers(1, 3), rng.integers(2, 6), rng.integers(2, 6)
        n = rng.integers(1, 6)
        cnt[cz : cz + sz, cy : cy + sy, cx : cx + sx] += n
        summ[cz : cz + sz, cy : cy + sy, cx : cx + sx] += rng.normal(
            size=(sz, sy, sx, cfg.POINT_FEATURES)
        ).astype(np.float32) * n
    return jnp.asarray(summ), jnp.asarray(cnt)


def test_vfe_mean_and_mask(frame):
    summ, cnt = frame
    feat, mask = model.vfe(summ, cnt)
    assert feat.shape == (*cfg.grid_shape(), cfg.VFE_CHANNELS)
    assert mask.shape == (*cfg.grid_shape(), 1)
    m = np.asarray(mask)
    assert set(np.unique(m)) <= {0.0, 1.0}
    # mean = sum / cnt where cnt > 0
    c = np.asarray(cnt)
    occ = c[..., 0] > 0
    np.testing.assert_allclose(
        np.asarray(feat)[occ],
        (np.asarray(summ) / np.maximum(c, 1.0))[occ],
        rtol=1e-6,
    )
    assert np.all(np.asarray(feat)[~occ] == 0.0)


def test_stage_output_shapes(weights, frame):
    summ, cnt = frame
    inter = model.run_backbone(weights, summ, cnt, use_pallas=False)
    for i, st in enumerate(cfg.BACKBONE3D_STAGES):
        feat, mask = inter[st.name]
        assert feat.shape == cfg.stage_output_shape(i)
        assert mask.shape == (*cfg.stage_output_shape(i)[:3], 1)


def test_occupancy_grows_through_regular_stages(weights, frame):
    """The mechanism behind the paper's Fig 8: regular sparse convs dilate
    the active set, so occupied fraction grows monotonically with depth."""
    summ, cnt = frame
    inter = model.run_backbone(weights, summ, cnt, use_pallas=False)
    frac = [float(np.asarray(inter["vfe"][1]).mean())]
    for st in cfg.BACKBONE3D_STAGES:
        frac.append(float(np.asarray(inter[st.name][1]).mean()))
    for a, b in zip(frac, frac[1:]):
        assert b >= a - 1e-6, frac


def test_features_masked_by_occupancy(weights, frame):
    summ, cnt = frame
    inter = model.run_backbone(weights, summ, cnt, use_pallas=False)
    for st in cfg.BACKBONE3D_STAGES:
        feat, mask = inter[st.name]
        inactive = np.asarray(mask)[..., 0] == 0.0
        assert np.all(np.asarray(feat)[inactive] == 0.0), st.name


def test_bev_head_shapes(weights, frame):
    summ, cnt = frame
    inter = model.run_backbone(weights, summ, cnt, use_pallas=False)
    cls, box, direc = inter["bev_head"]
    assert cls.shape == (cfg.NUM_ANCHORS,)
    assert box.shape == (cfg.NUM_ANCHORS, cfg.BOX_CODE_SIZE)
    assert direc.shape == (cfg.NUM_ANCHORS, 2)


def _rois(k=cfg.NUM_PROPOSALS, seed=11):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        np.stack(
            [
                rng.uniform(2, 44, k),
                rng.uniform(-20, 20, k),
                rng.uniform(-2.5, 0.5, k),
                rng.uniform(1, 5, k),
                rng.uniform(0.5, 2.5, k),
                rng.uniform(1, 2, k),
                rng.uniform(-np.pi, np.pi, k),
            ],
            axis=1,
        ).astype(np.float32)
    )


def test_roi_head_shapes_and_decode(weights, frame):
    summ, cnt = frame
    inter, scores, boxes = model.full_pipeline(
        weights, summ, cnt, _rois(), use_pallas=False
    )
    assert scores.shape == (cfg.NUM_PROPOSALS,)
    assert boxes.shape == (cfg.NUM_PROPOSALS, cfg.BOX_CODE_SIZE)
    # decoded dims stay positive (exp of clipped deltas)
    assert np.all(np.asarray(boxes)[:, 3:6] > 0.0)


def test_pallas_and_ref_paths_agree(weights, frame):
    """The invariant the AOT artifacts rely on: the kernels we bake equal
    the oracle path at pipeline scale, not just kernel scale."""
    summ, cnt = frame
    rois = _rois()
    _, s_ref, b_ref = model.full_pipeline(weights, summ, cnt, rois, use_pallas=False)
    _, s_pal, b_pal = model.full_pipeline(weights, summ, cnt, rois, use_pallas=True)
    np.testing.assert_allclose(s_pal, s_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(b_pal, b_ref, rtol=1e-3, atol=1e-3)


def test_map_to_bev_layout():
    """Channel layout contract with the rust decoder: (H, W, D*C) where z is
    the slower-varying factor of the folded channel dim."""
    d, h, w, c = 2, 4, 4, 3
    x = jnp.arange(d * h * w * c, dtype=jnp.float32).reshape(d, h, w, c)
    bev = model.map_to_bev(x)
    assert bev.shape == (h, w, d * c)
    np.testing.assert_array_equal(
        np.asarray(bev[1, 2]), np.asarray(jnp.concatenate([x[0, 1, 2], x[1, 1, 2]]))
    )


def test_empty_frame_runs(weights):
    """No points at all: every mask is 0, every feature 0, heads still run."""
    d, h, w = cfg.grid_shape()
    summ = jnp.zeros((d, h, w, cfg.POINT_FEATURES), jnp.float32)
    cnt = jnp.zeros((d, h, w, 1), jnp.float32)
    inter = model.run_backbone(weights, summ, cnt, use_pallas=False)
    for st in cfg.BACKBONE3D_STAGES:
        assert np.all(np.asarray(inter[st.name][0]) == 0.0)
    cls, box, direc = inter["bev_head"]
    assert np.all(np.isfinite(np.asarray(cls)))
