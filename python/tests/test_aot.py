"""AOT contract tests: the manifest + HLO artifacts the rust side consumes."""

import json
import pathlib

import pytest

from compile import aot, config as cfg

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


@pytest.fixture(scope="module")
def manifest():
    p = ARTIFACTS / "manifest.json"
    if not p.exists():
        pytest.skip("run `make artifacts` first")
    return json.loads(p.read_text())


def test_module_io_is_a_dag():
    """Every module input is either a primal input or produced earlier —
    the property the rust live-set analysis (paper Table II) depends on."""
    produced = {"points_sum", "points_cnt", "rois"}
    for name in cfg.MODULE_NAMES:
        io = aot.MODULE_IO[name]
        for i in io["inputs"]:
            assert i in produced, f"{name} consumes undeclared {i}"
        for o in io["outputs"]:
            assert o not in produced, f"{o} produced twice"
            produced.add(o)


def test_table2_live_sets_from_module_io():
    """Recompute paper Table II from the declared dataflow: the tensors
    crossing each split boundary."""
    order = list(cfg.MODULE_NAMES)

    def live_after(split_idx):
        prods = {}
        for m in order:
            for o in aot.MODULE_IO[m]["outputs"]:
                prods[o] = m
        head = set(order[: split_idx + 1])
        live = set()
        for m in order[split_idx + 1 :]:
            for i in aot.MODULE_IO[m]["inputs"]:
                if prods.get(i) in head:
                    live.add(i)
        return live

    # paper Table II (masks ride along with their features in our codec)
    assert live_after(order.index("conv1")) == {"conv1_feat", "conv1_mask"}
    assert live_after(order.index("conv2")) == {"conv2_feat", "conv2_mask"}
    assert live_after(order.index("conv3")) == {
        "conv2_feat", "conv3_feat", "conv3_mask",
    }
    assert live_after(order.index("conv4")) == {
        "conv2_feat", "conv3_feat", "conv4_feat",
    }


def test_manifest_covers_all_modules(manifest):
    names = [m["name"] for m in manifest["modules"]]
    assert names == list(cfg.MODULE_NAMES)
    for m in manifest["modules"]:
        assert (ARTIFACTS / m["artifact"]).exists()


def test_manifest_shapes_match_config(manifest):
    mods = {m["name"]: m for m in manifest["modules"]}
    d, h, w = cfg.grid_shape()
    assert mods["vfe"]["inputs"][0]["shape"] == [d, h, w, cfg.POINT_FEATURES]
    for i, st in enumerate(cfg.BACKBONE3D_STAGES):
        assert mods[st.name]["outputs"][0]["shape"] == list(
            cfg.stage_output_shape(i)
        )
    assert mods["bev_head"]["outputs"][0]["shape"] == [cfg.NUM_ANCHORS]
    assert mods["roi_head"]["inputs"][3]["shape"] == [
        cfg.NUM_PROPOSALS, cfg.BOX_CODE_SIZE,
    ]


def test_artifacts_contain_unelided_constants(manifest):
    """Baked weights must survive the text round-trip: no `constant({...})`
    placeholders (the rust parser cannot reconstruct elided literals)."""
    for m in manifest["modules"]:
        text = (ARTIFACTS / m["artifact"]).read_text()
        assert "constant({...})" not in text, m["name"]


def test_artifact_hashes_match(manifest):
    import hashlib

    for m in manifest["modules"]:
        text = (ARTIFACTS / m["artifact"]).read_text()
        assert hashlib.sha256(text.encode()).hexdigest() == m["sha256"]
