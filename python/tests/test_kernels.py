"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes / strides / occupancies; each property asserts
allclose against ref.py. These are the build-time gate for the AOT'd
kernels (interpret=True lowers them into the same HLO the rust side runs).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bev_conv import conv2d_fused
from compile.kernels.conv3d import conv3d_fused
from compile.kernels.roi_pool import roi_pool

RTOL, ATOL = 1e-4, 1e-4


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- conv3d


@st.composite
def conv3d_cases(draw):
    d = draw(st.sampled_from([2, 4, 8]))
    h = draw(st.sampled_from([4, 8, 16]))
    w = draw(st.sampled_from([4, 8, 16]))
    ci = draw(st.sampled_from([1, 3, 4, 8]))
    co = draw(st.sampled_from([1, 8, 16]))
    stride = draw(
        st.sampled_from([(1, 1, 1), (2, 1, 1), (1, 2, 2), (2, 2, 2)])
    )
    occupancy = draw(st.floats(0.0, 1.0))
    seed = draw(st.integers(0, 2**31 - 1))
    return d, h, w, ci, co, stride, occupancy, seed


@settings(max_examples=25, deadline=None)
@given(conv3d_cases())
def test_conv3d_matches_ref(case):
    d, h, w, ci, co, stride, occupancy, seed = case
    rng = _rng(seed)
    x = jnp.asarray(rng.normal(size=(d, h, w, ci)).astype(np.float32))
    wt = jnp.asarray(rng.normal(size=(3, 3, 3, ci, co)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(co,)).astype(np.float32))
    sz, sy, sx = stride
    mask = jnp.asarray(
        (rng.random((d // sz, h // sy, w // sx, 1)) < occupancy).astype(
            np.float32
        )
    )
    got = conv3d_fused(x, wt, b, mask, stride)
    want = ref.conv3d_ref(x, wt, b, mask, stride)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_conv3d_zero_mask_zeroes_output():
    rng = _rng(0)
    x = jnp.asarray(rng.normal(size=(4, 8, 8, 4)).astype(np.float32))
    wt = jnp.asarray(rng.normal(size=(3, 3, 3, 4, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    mask = jnp.zeros((4, 8, 8, 1), jnp.float32)
    out = conv3d_fused(x, wt, b, mask, (1, 1, 1))
    assert np.all(np.asarray(out) == 0.0)


def test_conv3d_output_nonnegative():
    # fused ReLU: outputs can never be negative
    rng = _rng(1)
    x = jnp.asarray(rng.normal(size=(4, 8, 8, 4)).astype(np.float32))
    wt = jnp.asarray(rng.normal(size=(3, 3, 3, 4, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    mask = jnp.ones((2, 8, 8, 1), jnp.float32)
    out = conv3d_fused(x, wt, b, mask, (2, 1, 1))
    assert np.asarray(out).min() >= 0.0


# ---------------------------------------------------------------- conv2d


@st.composite
def conv2d_cases(draw):
    h = draw(st.sampled_from([4, 8, 16, 32]))
    w = draw(st.sampled_from([4, 8, 16, 32]))
    ci = draw(st.sampled_from([1, 4, 16]))
    co = draw(st.sampled_from([1, 8, 32]))
    relu = draw(st.booleans())
    seed = draw(st.integers(0, 2**31 - 1))
    return h, w, ci, co, relu, seed


@settings(max_examples=20, deadline=None)
@given(conv2d_cases())
def test_conv2d_matches_ref(case):
    h, w, ci, co, relu, seed = case
    rng = _rng(seed)
    x = jnp.asarray(rng.normal(size=(h, w, ci)).astype(np.float32))
    wt = jnp.asarray(rng.normal(size=(3, 3, ci, co)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(co,)).astype(np.float32))
    got = conv2d_fused(x, wt, b, relu=relu)
    want = ref.conv2d_ref(x, wt, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_conv2d_odd_height_falls_back_to_row_tile_1():
    rng = _rng(2)
    x = jnp.asarray(rng.normal(size=(5, 8, 4)).astype(np.float32))
    wt = jnp.asarray(rng.normal(size=(3, 3, 4, 8)).astype(np.float32))
    b = jnp.zeros((8,), jnp.float32)
    np.testing.assert_allclose(
        conv2d_fused(x, wt, b), ref.conv2d_ref(x, wt, b), rtol=RTOL, atol=ATOL
    )


# --------------------------------------------------------------- roi pool


RANGE_MIN = (0.0, -23.04, -3.0)


@st.composite
def roi_cases(draw):
    d = draw(st.sampled_from([2, 4, 8]))
    h = draw(st.sampled_from([8, 16, 32]))
    c = draw(st.sampled_from([1, 8, 32]))
    k = draw(st.sampled_from([1, 8, 16, 24]))
    g = draw(st.sampled_from([2, 4]))
    seed = draw(st.integers(0, 2**31 - 1))
    return d, h, c, k, g, seed


def _random_rois(rng, k):
    return jnp.asarray(
        np.stack(
            [
                rng.uniform(-5, 50, k),   # cx (some out of range)
                rng.uniform(-30, 30, k),  # cy
                rng.uniform(-4, 2, k),    # cz
                rng.uniform(0.5, 5, k),   # l
                rng.uniform(0.5, 2.5, k), # w
                rng.uniform(0.5, 2.5, k), # h
                rng.uniform(-np.pi, np.pi, k),
            ],
            axis=1,
        ).astype(np.float32)
    )


@settings(max_examples=20, deadline=None)
@given(roi_cases())
def test_roi_pool_matches_ref(case):
    d, h, c, k, g, seed = case
    rng = _rng(seed)
    feat = jnp.asarray(rng.normal(size=(d, h, h, c)).astype(np.float32))
    rois = _random_rois(rng, k)
    vox = (4.0 / d, 46.08 / h, 46.08 / h)
    got = roi_pool(feat, rois, g, RANGE_MIN, vox)
    want = ref.roi_pool_ref(feat, rois, g, RANGE_MIN, vox)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_roi_pool_out_of_range_is_zero():
    rng = _rng(3)
    feat = jnp.asarray(rng.normal(size=(4, 16, 16, 8)).astype(np.float32))
    # boxes far outside the range -> all grid points invalid -> zeros
    rois = jnp.asarray(
        np.tile(
            np.array([[500.0, 500.0, 50.0, 2.0, 2.0, 2.0, 0.3]], np.float32),
            (8, 1),
        )
    )
    out = roi_pool(feat, rois, 4, RANGE_MIN, (1.0, 0.36, 0.36))
    assert np.all(np.asarray(out) == 0.0)


def test_roi_pool_rotation_invariance_of_center_point():
    # An odd grid has no exact-center sample; instead check that rotating a
    # box by exactly pi maps the grid onto itself mirrored — total energy
    # (sum of squares) over gathered features is identical.
    rng = _rng(4)
    feat = jnp.asarray(rng.normal(size=(4, 32, 32, 4)).astype(np.float32))
    base = np.array([[23.0, 0.0, -1.0, 4.0, 2.0, 1.5, 0.7]], np.float32)
    rot = base.copy()
    rot[0, 6] += np.pi
    vox = (1.0, 46.08 / 32, 46.08 / 32)
    a = np.asarray(roi_pool(jnp.asarray(feat), jnp.asarray(base), 4, RANGE_MIN, vox))
    b = np.asarray(roi_pool(jnp.asarray(feat), jnp.asarray(rot), 4, RANGE_MIN, vox))
    np.testing.assert_allclose(
        np.sort(a.ravel()), np.sort(b.ravel()), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------- mask semantics


@settings(max_examples=15, deadline=None)
@given(
    st.sampled_from([(1, 1, 1), (2, 1, 1), (2, 2, 2)]),
    st.floats(0.0, 0.3),
    st.integers(0, 2**31 - 1),
)
def test_dilate_mask_superset_of_stride_mask(stride, occ, seed):
    """Regular sparse conv's active set contains the submanifold one."""
    rng = _rng(seed)
    mask = jnp.asarray((rng.random((8, 16, 16, 1)) < occ).astype(np.float32))
    dil = np.asarray(ref.dilate_mask_ref(mask, stride))
    sub = np.asarray(ref.stride_mask_ref(mask, stride))
    assert dil.shape == sub.shape
    assert np.all(dil >= sub)


@settings(max_examples=15, deadline=None)
@given(st.floats(0.0, 1.0), st.integers(0, 2**31 - 1))
def test_dilate_mask_monotone_in_occupancy(occ, seed):
    """More active inputs can only grow the dilated set (codec-size
    monotonicity on the rust side relies on this)."""
    rng = _rng(seed)
    base = rng.random((8, 16, 16, 1))
    m1 = jnp.asarray((base < occ * 0.5).astype(np.float32))
    m2 = jnp.asarray((base < occ).astype(np.float32))
    d1 = np.asarray(ref.dilate_mask_ref(m1, (1, 1, 1)))
    d2 = np.asarray(ref.dilate_mask_ref(m2, (1, 1, 1)))
    assert np.all(d2 >= d1)
