"""AOT: lower every L2 module to an HLO-text artifact + manifest.json.

This is the only place python touches the pipeline — it runs once at build
time (``make artifacts``); the rust coordinator is self-contained afterwards.

Interchange format is HLO **text**, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import hashlib
import json
import pathlib
import time

import jax
from jax._src.lib import xla_client as xc

from . import config as cfg
from . import model

# Dataflow declaration of the OpenPCDet-style module chain. The rust side
# derives split-point live sets (paper Table II) from exactly this graph.
MODULE_IO = {
    "vfe": {
        "inputs": ["points_sum", "points_cnt"],
        "outputs": ["vfe_feat", "vfe_mask"],
    },
    "conv1": {
        "inputs": ["vfe_feat", "vfe_mask"],
        "outputs": ["conv1_feat", "conv1_mask"],
    },
    "conv2": {
        "inputs": ["conv1_feat", "conv1_mask"],
        "outputs": ["conv2_feat", "conv2_mask"],
    },
    "conv3": {
        "inputs": ["conv2_feat", "conv2_mask"],
        "outputs": ["conv3_feat", "conv3_mask"],
    },
    "conv4": {
        "inputs": ["conv3_feat", "conv3_mask"],
        "outputs": ["conv4_feat", "conv4_mask"],
    },
    "bev_head": {
        "inputs": ["conv4_feat"],
        "outputs": ["cls_logits", "box_preds", "dir_logits"],
    },
    "roi_head": {
        "inputs": ["conv2_feat", "conv3_feat", "conv4_feat", "rois"],
        "outputs": ["roi_scores", "roi_boxes"],
    },
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked weights must survive the text
    # round-trip — default printing elides them as `constant({...})`,
    # which the rust-side text parser cannot reconstruct.
    return comp.as_hlo_text(print_large_constants=True)


def lower_module(fn, input_shapes):
    specs = [
        jax.ShapeDtypeStruct(s, jax.numpy.float32) for s in input_shapes
    ]
    return jax.jit(fn).lower(*specs)


def export_all(out_dir: pathlib.Path, use_pallas: bool = True) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    weights = model.init_weights()
    fns = model.module_fns(weights, use_pallas=use_pallas)

    manifest = {
        "version": 1,
        "generated_unix": int(time.time()),
        "use_pallas": use_pallas,
        "config": cfg.manifest_dict(),
        "modules": [],
    }

    for name in cfg.MODULE_NAMES:
        fn, input_shapes = fns[name]
        lowered = lower_module(fn, input_shapes)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)

        out_shapes = [
            list(o.shape) for o in jax.tree_util.tree_leaves(
                jax.eval_shape(fn, *[
                    jax.ShapeDtypeStruct(s, jax.numpy.float32)
                    for s in input_shapes
                ])
            )
        ]
        io = MODULE_IO[name]
        assert len(io["inputs"]) == len(input_shapes), name
        assert len(io["outputs"]) == len(out_shapes), name
        manifest["modules"].append(
            {
                "name": name,
                "artifact": path.name,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "inputs": [
                    {"name": n, "shape": list(s)}
                    for n, s in zip(io["inputs"], input_shapes)
                ],
                "outputs": [
                    {"name": n, "shape": s}
                    for n, s in zip(io["outputs"], out_shapes)
                ],
            }
        )
        print(f"  {name:<9} -> {path.name:<18} {len(text)/1e6:.2f} MB text")

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out_dir / 'manifest.json'}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="bake the ref.py path instead of the Pallas kernels "
        "(debug / A-B artifact comparison)",
    )
    args = ap.parse_args()
    export_all(pathlib.Path(args.out_dir), use_pallas=not args.no_pallas)


if __name__ == "__main__":
    main()
