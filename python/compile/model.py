"""L2: Voxel R-CNN compute graph as independently-exportable modules.

Mirrors OpenPCDet's module list (paper Fig 3/5):

    pre-process (rust) -> (1) VFE -> (2) Backbone3D [conv1..conv4]
      -> (3) MapToBEV -> (4) Backbone2D -> (5) DenseHead
      -> [rust: sigmoid + top-K + NMS] -> (6) RoIHead

Every module is a pure function over (weights, inputs) with fixed shapes, so
``aot.py`` can lower each one to its own HLO artifact and the rust
coordinator can cut the chain at any module boundary (the paper's split
points). Occupancy masks are carried through the 3D backbone to emulate
sparse-conv semantics (spconv): regular stages dilate the active set,
which is exactly the mechanism behind the paper's transfer-size growth
(Fig 8). See DESIGN.md §3.

Set ``use_pallas=False`` to swap every Pallas kernel for its ref.py oracle —
the pytest suite asserts both paths agree, and AOT bakes the pallas path.
"""

import jax
import jax.numpy as jnp

from . import config as cfg
from .kernels import ref
from .kernels.bev_conv import conv2d_fused
from .kernels.conv3d import conv3d_fused
from .kernels.roi_pool import roi_pool

# --------------------------------------------------------------------------
# weights
# --------------------------------------------------------------------------


def _conv3d_w(key, cin, cout):
    k1, k2 = jax.random.split(key)
    fan_in = 27 * cin
    w = jax.random.normal(k1, (3, 3, 3, cin, cout), jnp.float32)
    return {
        "w": w * (2.0 / fan_in) ** 0.5,
        "b": 0.01 * jax.random.normal(k2, (cout,), jnp.float32),
    }


def _conv2d_w(key, cin, cout, k=3):
    k1, k2 = jax.random.split(key)
    fan_in = k * k * cin
    w = jax.random.normal(k1, (k, k, cin, cout), jnp.float32)
    return {
        "w": w * (2.0 / fan_in) ** 0.5,
        "b": 0.01 * jax.random.normal(k2, (cout,), jnp.float32),
    }


def _linear_w(key, cin, cout):
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (cin, cout), jnp.float32)
    return {
        "w": w * (2.0 / cin) ** 0.5,
        "b": 0.01 * jax.random.normal(k2, (cout,), jnp.float32),
    }


def init_weights(seed: int = cfg.WEIGHTS_SEED) -> dict:
    """Deterministic seeded weights (DESIGN.md §3: the paper reports no
    accuracy numbers, so time/bytes — which are weight-independent — are
    what we reproduce; correctness is split==unsplit equivalence)."""
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 32))
    w = {}
    for st in cfg.BACKBONE3D_STAGES:
        w[st.name] = _conv3d_w(next(keys), st.cin, st.cout)
    w["bev"] = {
        "block1": _conv2d_w(next(keys), cfg.BEV_CHANNELS, cfg.BEV_BACKBONE_CHANNELS),
        "block2": _conv2d_w(
            next(keys), cfg.BEV_BACKBONE_CHANNELS, cfg.BEV_BACKBONE_CHANNELS
        ),
        "cls": _linear_w(next(keys), cfg.BEV_BACKBONE_CHANNELS, cfg.ANCHORS_PER_CELL),
        "box": _linear_w(
            next(keys),
            cfg.BEV_BACKBONE_CHANNELS,
            cfg.ANCHORS_PER_CELL * cfg.BOX_CODE_SIZE,
        ),
        "dir": _linear_w(
            next(keys), cfg.BEV_BACKBONE_CHANNELS, cfg.ANCHORS_PER_CELL * 2
        ),
    }
    w["roi"] = {
        "proj": {
            s: _linear_w(
                next(keys),
                dict(
                    conv2=cfg.BACKBONE3D_STAGES[1].cout,
                    conv3=cfg.BACKBONE3D_STAGES[2].cout,
                    conv4=cfg.BACKBONE3D_STAGES[3].cout,
                )[s],
                cfg.ROI_POOL_CHANNELS,
            )
            for s in cfg.ROI_POOL_SCALES
        },
        "mlp1": _linear_w(
            next(keys), len(cfg.ROI_POOL_SCALES) * cfg.ROI_POOL_CHANNELS, cfg.ROI_MLP
        ),
        "mlp2": _linear_w(next(keys), cfg.ROI_MLP, cfg.ROI_MLP),
        "fc1": _linear_w(next(keys), 2 * cfg.ROI_MLP, cfg.ROI_FC),
        "fc2": _linear_w(next(keys), cfg.ROI_FC, cfg.ROI_FC),
        "cls": _linear_w(next(keys), cfg.ROI_FC, 1),
        "reg": _linear_w(next(keys), cfg.ROI_FC, cfg.BOX_CODE_SIZE),
    }
    return w


# --------------------------------------------------------------------------
# modules
# --------------------------------------------------------------------------


def vfe(points_sum, points_cnt):
    """(1) MeanVFE: per-voxel mean of point features + occupancy mask.

    points_sum: (D, H, W, 4) summed point features per voxel (rust scatter)
    points_cnt: (D, H, W, 1) point count per voxel
    returns (feat (D, H, W, 4), mask (D, H, W, 1))
    """
    mask = (points_cnt > 0).astype(jnp.float32)
    feat = points_sum / jnp.maximum(points_cnt, 1.0)
    return feat * mask, mask


def conv_stage(weights, stage: cfg.ConvStage, x, mask, use_pallas=True):
    """One Backbone3D stage: fused conv with sparse-conv occupancy semantics.

    returns (feat, mask_out) at the stage's output resolution.
    """
    if stage.submanifold:
        mask_out = ref.stride_mask_ref(mask, stage.stride)
    else:
        mask_out = ref.dilate_mask_ref(mask, stage.stride)
    conv = conv3d_fused if use_pallas else ref.conv3d_ref
    w = weights[stage.name]
    return conv(x, w["w"], w["b"], mask_out, stage.stride), mask_out


def _stage(name):
    idx = [s.name for s in cfg.BACKBONE3D_STAGES].index(name)
    return cfg.BACKBONE3D_STAGES[idx]


def conv1(weights, x, mask, use_pallas=True):
    return conv_stage(weights, _stage("conv1"), x, mask, use_pallas)


def conv2(weights, x, mask, use_pallas=True):
    return conv_stage(weights, _stage("conv2"), x, mask, use_pallas)


def conv3(weights, x, mask, use_pallas=True):
    return conv_stage(weights, _stage("conv3"), x, mask, use_pallas)


def conv4(weights, x, mask, use_pallas=True):
    return conv_stage(weights, _stage("conv4"), x, mask, use_pallas)


def map_to_bev(x):
    """(3) fold z into channels: (D, H, W, C) -> (H, W, D*C)."""
    d, h, w, c = x.shape
    return jnp.transpose(x, (1, 2, 0, 3)).reshape(h, w, d * c)


def bev_head(weights, conv4_feat, use_pallas=True):
    """(3)+(4)+(5): MapToBEV -> Backbone2D -> anchor DenseHead.

    conv4_feat: (2, 32, 32, 128).
    returns cls (A,), box (A, 7), dir (A, 2) raw logits/deltas, anchor-major
    ordering (h, w, class, rotation) that the rust decoder mirrors.
    """
    wb = weights["bev"]
    conv = conv2d_fused if use_pallas else ref.conv2d_ref
    x = map_to_bev(conv4_feat)  # (32, 32, 256)
    x = conv(x, wb["block1"]["w"], wb["block1"]["b"])
    x = conv(x, wb["block2"]["w"], wb["block2"]["b"])  # (32, 32, 64)

    hw = cfg.BEV_H * cfg.BEV_W
    flat = x.reshape(hw, cfg.BEV_BACKBONE_CHANNELS)
    cls = flat @ wb["cls"]["w"] + wb["cls"]["b"]  # (hw, 6)
    box = flat @ wb["box"]["w"] + wb["box"]["b"]  # (hw, 42)
    direc = flat @ wb["dir"]["w"] + wb["dir"]["b"]  # (hw, 12)
    a = cfg.NUM_ANCHORS
    return (
        cls.reshape(a),
        box.reshape(a, cfg.BOX_CODE_SIZE),
        direc.reshape(a, 2),
    )


def _scale_voxel_size(scale_name):
    """Metric voxel size (vz, vy, vx) of a backbone scale's grid."""
    d, h, w, _ = cfg.stage_output_shape(
        [s.name for s in cfg.BACKBONE3D_STAGES].index(scale_name)
    )
    z0, z1 = cfg.PC_RANGE["z"]
    y0, y1 = cfg.PC_RANGE["y"]
    x0, x1 = cfg.PC_RANGE["x"]
    return ((z1 - z0) / d, (y1 - y0) / h, (x1 - x0) / w)


RANGE_MIN = (cfg.PC_RANGE["x"][0], cfg.PC_RANGE["y"][0], cfg.PC_RANGE["z"][0])


def roi_head(weights, conv2_feat, conv3_feat, conv4_feat, rois, use_pallas=True):
    """(6) Voxel RoI pooling over three scales + per-point MLP refinement.

    Mirrors Voxel R-CNN's head structure (and its Table I cost dominance):
    a 6^3 sample grid per RoI over three backbone scales, a shared MLP over
    every grid point — the bulk of the head's FLOPs, as the original's
    grid-feature FC stack is — then permutation-invariant pooling and the
    cls/reg towers.

    rois: (K, 7) metric proposal boxes from the rust-side NMS.
    returns (scores (K,), boxes (K, 7) refined, decoded).
    """
    wr = weights["roi"]
    pool = roi_pool if use_pallas else ref.roi_pool_ref
    feats = {"conv2": conv2_feat, "conv3": conv3_feat, "conv4": conv4_feat}

    per_scale = []
    for s in cfg.ROI_POOL_SCALES:
        pooled = pool(
            feats[s], rois, cfg.ROI_GRID, RANGE_MIN, _scale_voxel_size(s)
        )  # (K, G^3, C_s)
        p = wr["proj"][s]
        per_scale.append(jax.nn.relu(pooled @ p["w"] + p["b"]))  # (K, G^3, 16)
    x = jnp.concatenate(per_scale, axis=-1)  # (K, G^3, 48)

    # shared per-grid-point MLP (the head's compute bulk)
    x = jax.nn.relu(x @ wr["mlp1"]["w"] + wr["mlp1"]["b"])  # (K, G^3, 128)
    x = jax.nn.relu(x @ wr["mlp2"]["w"] + wr["mlp2"]["b"])  # (K, G^3, 128)
    # permutation-invariant pool over the grid
    x = jnp.concatenate([jnp.mean(x, axis=1), jnp.max(x, axis=1)], axis=-1)

    x = jax.nn.relu(x @ wr["fc1"]["w"] + wr["fc1"]["b"])
    x = jax.nn.relu(x @ wr["fc2"]["w"] + wr["fc2"]["b"])
    scores = (x @ wr["cls"]["w"] + wr["cls"]["b"])[:, 0]  # (K,)
    deltas = x @ wr["reg"]["w"] + wr["reg"]["b"]  # (K, 7)

    # residual decode in the RoI local frame (Voxel R-CNN style, simplified)
    diag = jnp.sqrt(rois[:, 3] ** 2 + rois[:, 4] ** 2)
    cx = rois[:, 0] + deltas[:, 0] * diag
    cy = rois[:, 1] + deltas[:, 1] * diag
    cz = rois[:, 2] + deltas[:, 2] * rois[:, 5]
    dlwh = jnp.clip(deltas[:, 3:6], -2.0, 2.0)
    lwh = rois[:, 3:6] * jnp.exp(dlwh)
    ry = rois[:, 6] + deltas[:, 6]
    boxes = jnp.concatenate(
        [cx[:, None], cy[:, None], cz[:, None], lwh, ry[:, None]], axis=-1
    )
    return scores, boxes


# --------------------------------------------------------------------------
# module registry for AOT + the composed pipeline for tests
# --------------------------------------------------------------------------


def module_fns(weights, use_pallas=True):
    """name -> (fn, example_input_shapes). Weights are closed over, so AOT
    bakes them into the HLO as constants (folded by XLA)."""
    d, h, w = cfg.grid_shape()
    s1 = cfg.stage_output_shape(0)
    s2 = cfg.stage_output_shape(1)
    s3 = cfg.stage_output_shape(2)
    s4 = cfg.stage_output_shape(3)

    def m(shape):
        return (*shape[:3], 1)

    def stage_fn(f):
        return lambda x, mask: f(weights, x, mask, use_pallas)

    return {
        "vfe": (vfe, [(d, h, w, cfg.POINT_FEATURES), (d, h, w, 1)]),
        "conv1": (stage_fn(conv1), [(d, h, w, cfg.VFE_CHANNELS), (d, h, w, 1)]),
        "conv2": (stage_fn(conv2), [s1, m(s1)]),
        "conv3": (stage_fn(conv3), [s2, m(s2)]),
        "conv4": (stage_fn(conv4), [s3, m(s3)]),
        "bev_head": (lambda x: bev_head(weights, x, use_pallas), [s4]),
        "roi_head": (
            lambda c2, c3, c4, rois: roi_head(
                weights, c2, c3, c4, rois, use_pallas
            ),
            [s2, s3, s4, (cfg.NUM_PROPOSALS, cfg.BOX_CODE_SIZE)],
        ),
    }


def run_backbone(weights, points_sum, points_cnt, use_pallas=True):
    """pre-NMS pipeline: VFE through DenseHead. Returns intermediates dict."""
    out = {}
    feat, mask = vfe(points_sum, points_cnt)
    out["vfe"] = (feat, mask)
    for st in cfg.BACKBONE3D_STAGES:
        feat, mask = conv_stage(weights, st, feat, mask, use_pallas)
        out[st.name] = (feat, mask)
    out["bev_head"] = bev_head(weights, out["conv4"][0], use_pallas)
    return out


def full_pipeline(weights, points_sum, points_cnt, rois, use_pallas=True):
    """End-to-end minus the (rust-side) NMS: proposals are an input."""
    inter = run_backbone(weights, points_sum, points_cnt, use_pallas)
    scores, boxes = roi_head(
        weights,
        inter["conv2"][0],
        inter["conv3"][0],
        inter["conv4"][0],
        rois,
        use_pallas,
    )
    return inter, scores, boxes
