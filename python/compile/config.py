"""Model geometry shared by every layer of the stack.

All shapes are fixed at AOT time (PJRT executables are static-shape); the
values here are serialized into ``artifacts/manifest.json`` so the rust
coordinator never hardcodes a dimension.

The grid is a scaled-down KITTI front-camera volume (see DESIGN.md §3):
paper grid 1408x1600x41 @ 0.05 m -> ours 128x128x16 @ 0.36/0.25 m. Axis
order everywhere is (z, y, x, channels) a.k.a. DHWC.
"""

from dataclasses import dataclass, field


# ---------------------------------------------------------------- geometry
# Point-cloud range in metres, KITTI-like front FoV.
PC_RANGE = {
    "x": (0.0, 46.08),
    "y": (-23.04, 23.04),
    "z": (-3.0, 1.0),
}
VOXEL_SIZE = (0.25, 0.36, 0.36)  # (z, y, x) metres

# Dense voxel grid (z, y, x).
GRID_D = 16
GRID_H = 128
GRID_W = 128

# Raw point features: x, y, z, intensity.
POINT_FEATURES = 4

# VFE output channels (MeanVFE: mean of point features per voxel).
VFE_CHANNELS = 4

# ------------------------------------------------------------- backbone 3d
# Four stages mirroring Voxel R-CNN's 1x/2x/4x/8x blocks. conv2 downsamples
# z only (DESIGN.md §3 explains why on the scaled grid).
@dataclass(frozen=True)
class ConvStage:
    name: str
    cin: int
    cout: int
    stride: tuple  # (z, y, x)
    # submanifold: occupancy mask is NOT dilated (SubMConv3d semantics);
    # regular sparse conv dilates the active set by the kernel footprint.
    submanifold: bool


# conv1 is submanifold (SubMConv3d), exactly like Voxel R-CNN's conv_input/
# conv1 blocks: the active set does not dilate until the first strided
# SparseConv3d (conv2). This is what keeps the paper's conv1 transfer only
# ~6x the VFE transfer (Fig 8) instead of blowing up by the kernel footprint.
# Channel widths are Voxel R-CNN's divided by 2 — the single-core 2.1 GHz
# CPU testbed needs ~4x fewer conv FLOPs to keep per-frame latency in the
# regime where many-frame sweeps are practical (DESIGN.md §3 scaling).
BACKBONE3D_STAGES = (
    ConvStage("conv1", VFE_CHANNELS, 16, (1, 1, 1), submanifold=True),
    ConvStage("conv2", 16, 16, (2, 1, 1), submanifold=False),
    ConvStage("conv3", 16, 32, (2, 2, 2), submanifold=False),
    ConvStage("conv4", 32, 64, (2, 2, 2), submanifold=False),
)

KERNEL_SIZE = 3  # all 3d convs are 3x3x3


def stage_output_shape(stage_idx: int) -> tuple:
    """(D, H, W, C) after BACKBONE3D_STAGES[stage_idx]."""
    d, h, w = GRID_D, GRID_H, GRID_W
    for i, st in enumerate(BACKBONE3D_STAGES):
        sz, sy, sx = st.stride
        d, h, w = d // sz, h // sy, w // sx
        if i == stage_idx:
            return (d, h, w, st.cout)
    raise IndexError(stage_idx)


# --------------------------------------------------------------- bev / rpn
# MapToBEV folds conv4's z dim into channels.
BEV_D, BEV_H, BEV_W, _C4 = stage_output_shape(3)
BEV_CHANNELS = BEV_D * _C4          # 2 * 128 = 256
BEV_BACKBONE_CHANNELS = 64          # backbone2d working width

NUM_CLASSES = 3                      # Car, Pedestrian, Cyclist
ANCHOR_ROTATIONS = (0.0, 1.5707963)  # 0 and pi/2
# (l, w, h) per class, KITTI metric priors.
ANCHOR_SIZES = (
    (3.9, 1.6, 1.56),   # Car
    (0.8, 0.6, 1.73),   # Pedestrian
    (1.76, 0.6, 1.73),  # Cyclist
)
ANCHOR_Z = (-1.0, -0.6, -0.6)        # anchor center z per class
ANCHORS_PER_CELL = NUM_CLASSES * len(ANCHOR_ROTATIONS)  # 6
NUM_ANCHORS = BEV_H * BEV_W * ANCHORS_PER_CELL
BOX_CODE_SIZE = 7                    # x, y, z, l, w, h, ry

# ---------------------------------------------------------------- roi head
NUM_PROPOSALS = 96      # top-K after rust-side NMS
ROI_GRID = 6            # 6x6x6 grid points per RoI per scale (Voxel R-CNN)
ROI_POOL_SCALES = ("conv2", "conv3", "conv4")
ROI_POOL_CHANNELS = 16  # per-scale projection width before the point MLP
ROI_MLP = 128           # shared per-grid-point MLP width (the head's bulk —
                        # like Voxel R-CNN's, the RoI head dominates Table I)
ROI_FC = 128            # post-pool FC width

MODULE_NAMES = (
    "vfe",
    "conv1",
    "conv2",
    "conv3",
    "conv4",
    "bev_head",
    "roi_head",
)

WEIGHTS_SEED = 20250710


def grid_shape() -> tuple:
    return (GRID_D, GRID_H, GRID_W)


def manifest_dict() -> dict:
    """Everything the rust side needs, JSON-serializable."""
    return {
        "pc_range": PC_RANGE,
        "voxel_size": list(VOXEL_SIZE),
        "grid": [GRID_D, GRID_H, GRID_W],
        "point_features": POINT_FEATURES,
        "vfe_channels": VFE_CHANNELS,
        "stages": [
            {
                "name": s.name,
                "cin": s.cin,
                "cout": s.cout,
                "stride": list(s.stride),
                "submanifold": s.submanifold,
                "out_shape": list(stage_output_shape(i)),
            }
            for i, s in enumerate(BACKBONE3D_STAGES)
        ],
        "bev": {
            "h": BEV_H,
            "w": BEV_W,
            "channels": BEV_CHANNELS,
            "backbone_channels": BEV_BACKBONE_CHANNELS,
        },
        "num_classes": NUM_CLASSES,
        "anchor_sizes": [list(a) for a in ANCHOR_SIZES],
        "anchor_z": list(ANCHOR_Z),
        "anchor_rotations": list(ANCHOR_ROTATIONS),
        "anchors_per_cell": ANCHORS_PER_CELL,
        "num_anchors": NUM_ANCHORS,
        "box_code_size": BOX_CODE_SIZE,
        "num_proposals": NUM_PROPOSALS,
        "roi_grid": ROI_GRID,
        "roi_pool_scales": list(ROI_POOL_SCALES),
        "roi_pool_channels": ROI_POOL_CHANNELS,
        "weights_seed": WEIGHTS_SEED,
    }
