"""Pallas voxel RoI grid pooling kernel.

Grid walks RoI blocks; each program computes the metric-space G^3 sample
grid of its RoIs (rotation included), converts to voxel indices at this
backbone scale, and gathers features with a batched take — the TPU-shaped
replacement for the warp-per-RoI CUDA kernel in Voxel R-CNN's RoI head
(batched vector gathers instead of warp shuffles). interpret=True (CPU PJRT).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROI_BLOCK = 8


def _roi_pool_kernel(
    feat_ref, roi_ref, o_ref, *, grid_size, range_min, voxel_size, block
):
    """feat_ref: (D, H, W, C) whole scale; roi_ref: (RB, 7); o_ref: (RB, G^3, C)."""
    d, h, w, c = feat_ref.shape
    g = grid_size
    x0, y0, z0 = range_min
    vz, vy, vx = voxel_size

    rois = roi_ref[...]  # (RB, 7)

    # Box-frame grid offsets, cell centers in [-0.5, 0.5] (matches ref.py).
    lin = (jnp.arange(g, dtype=jnp.float32) + 0.5) / g - 0.5
    dz, dy, dx = jnp.meshgrid(lin, lin, lin, indexing="ij")
    local = jnp.stack([dx.ravel(), dy.ravel(), dz.ravel()], axis=-1)  # (G^3, 3)

    dims = rois[:, 3:6]
    scaled = local[None] * dims[:, None, :]  # (RB, G^3, 3)
    ry = rois[:, 6]
    cos, sin = jnp.cos(ry)[:, None], jnp.sin(ry)[:, None]
    px = scaled[..., 0] * cos - scaled[..., 1] * sin + rois[:, None, 0]
    py = scaled[..., 0] * sin + scaled[..., 1] * cos + rois[:, None, 1]
    pz = scaled[..., 2] + rois[:, None, 2]

    ix = jnp.floor((px - x0) / vx).astype(jnp.int32)
    iy = jnp.floor((py - y0) / vy).astype(jnp.int32)
    iz = jnp.floor((pz - z0) / vz).astype(jnp.int32)
    valid = (
        (ix >= 0) & (ix < w) & (iy >= 0) & (iy < h) & (iz >= 0) & (iz < d)
    )
    flat = (
        jnp.clip(iz, 0, d - 1) * (h * w)
        + jnp.clip(iy, 0, h - 1) * w
        + jnp.clip(ix, 0, w - 1)
    )  # (RB, G^3)

    feat = feat_ref[...].reshape(d * h * w, c)
    gathered = jnp.take(feat, flat.reshape(block * g * g * g), axis=0)
    gathered = gathered.reshape(block, g * g * g, c)
    o_ref[...] = gathered * valid[..., None].astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("grid_size", "range_min", "voxel_size")
)
def roi_pool(feat, rois, grid_size, range_min, voxel_size):
    """Drop-in for ref.roi_pool_ref.

    feat: (D, H, W, C); rois: (K, 7); returns (K, G^3, C).
    range_min / voxel_size are python tuples (compile-time constants).
    """
    k = rois.shape[0]
    c = feat.shape[-1]
    g3 = grid_size**3
    block = ROI_BLOCK if k % ROI_BLOCK == 0 else 1
    kernel = functools.partial(
        _roi_pool_kernel,
        grid_size=grid_size,
        range_min=range_min,
        voxel_size=voxel_size,
        block=block,
    )
    return pl.pallas_call(
        kernel,
        grid=(k // block,),
        in_specs=[
            pl.BlockSpec(feat.shape, lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((block, 7), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, g3, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, g3, c), jnp.float32),
        interpret=True,
    )(feat, rois)
