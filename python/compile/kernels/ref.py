"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the pytest/hypothesis suites compare the kernels
against, and the alternative compute path (``use_pallas=False``) used to
cross-check the AOT'd pipeline end to end.
"""

import jax
import jax.numpy as jnp


def conv3d_ref(x, w, b, mask, stride):
    """Fused 3x3x3 conv + bias + ReLU + occupancy-mask multiply.

    x:      (D, H, W, Ci)  float32, unpadded
    w:      (3, 3, 3, Ci, Co)
    b:      (Co,)
    mask:   (Do, Ho, Wo, 1) occupancy of the *output* active set
    stride: (sz, sy, sx)
    returns (Do, Ho, Wo, Co)
    """
    out = jax.lax.conv_general_dilated(
        x[None],
        w,
        window_strides=stride,
        padding=[(1, 1), (1, 1), (1, 1)],
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"),
    )[0]
    return jax.nn.relu(out + b) * mask


def conv2d_ref(x, w, b, relu=True):
    """Fused 3x3 2D conv (stride 1, SAME) + bias (+ ReLU).

    x: (H, W, Ci), w: (3, 3, Ci, Co), b: (Co,)
    """
    out = jax.lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(1, 1),
        padding=[(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]
    out = out + b
    return jax.nn.relu(out) if relu else out


def dilate_mask_ref(mask, stride):
    """Occupancy dilation of a regular (non-submanifold) sparse conv.

    A 3x3x3 max-pool with the conv's stride: an output site is active iff
    any input site under the kernel footprint is active. mask: (D, H, W, 1).
    """
    return jax.lax.reduce_window(
        mask,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(3, 3, 3, 1),
        window_strides=(*stride, 1),
        padding=[(1, 1), (1, 1), (1, 1), (0, 0)],
    )


def stride_mask_ref(mask, stride):
    """Occupancy of a submanifold strided conv: subsample, no dilation."""
    sz, sy, sx = stride
    return mask[::sz, ::sy, ::sx]


def roi_grid_points_ref(rois, grid_size):
    """Metric-space sample points of a GxGxG grid inside each rotated box.

    rois: (K, 7) = (cx, cy, cz, l, w, h, ry). returns (K, G^3, 3) xyz.
    """
    g = grid_size
    # grid point offsets in the box frame, cell centers in [-0.5, 0.5]
    lin = (jnp.arange(g, dtype=jnp.float32) + 0.5) / g - 0.5
    dz, dy, dx = jnp.meshgrid(lin, lin, lin, indexing="ij")
    local = jnp.stack([dx.ravel(), dy.ravel(), dz.ravel()], axis=-1)  # (G^3, 3)

    dims = rois[:, 3:6]  # (l, w, h)
    scaled = local[None] * dims[:, None, :]  # (K, G^3, 3) box-frame offsets
    ry = rois[:, 6]
    c, s = jnp.cos(ry), jnp.sin(ry)
    x = scaled[..., 0] * c[:, None] - scaled[..., 1] * s[:, None]
    y = scaled[..., 0] * s[:, None] + scaled[..., 1] * c[:, None]
    z = scaled[..., 2]
    return jnp.stack([x, y, z], axis=-1) + rois[:, None, 0:3]


def roi_pool_ref(feat, rois, grid_size, range_min, voxel_size):
    """Voxel RoI grid pooling: nearest-voxel gather of G^3 points per RoI.

    feat:       (D, H, W, C) one backbone scale
    rois:       (K, 7) metric boxes
    range_min:  (x0, y0, z0) of the point-cloud range
    voxel_size: (vz, vy, vx) metres per voxel *at this scale*
    returns     (K, G^3, C); out-of-range points contribute zeros.
    """
    d, h, w, c = feat.shape
    pts = roi_grid_points_ref(rois, grid_size)  # (K, G^3, 3) xyz
    x0, y0, z0 = range_min
    vz, vy, vx = voxel_size
    ix = jnp.floor((pts[..., 0] - x0) / vx).astype(jnp.int32)
    iy = jnp.floor((pts[..., 1] - y0) / vy).astype(jnp.int32)
    iz = jnp.floor((pts[..., 2] - z0) / vz).astype(jnp.int32)
    valid = (
        (ix >= 0) & (ix < w) & (iy >= 0) & (iy < h) & (iz >= 0) & (iz < d)
    )
    flat = (
        jnp.clip(iz, 0, d - 1) * (h * w)
        + jnp.clip(iy, 0, h - 1) * w
        + jnp.clip(ix, 0, w - 1)
    )
    gathered = feat.reshape(d * h * w, c)[flat]  # (K, G^3, C)
    return gathered * valid[..., None].astype(feat.dtype)
