"""Pallas fused 3D convolution: conv3x3x3 + bias + ReLU + occupancy mask.

TPU-shaped (see DESIGN.md §Hardware-Adaptation): the grid walks the kernel's
z-taps; each program stages the z-shifted, z-strided input volume once and
reduces its 9 in-plane taps as (Do·Ho·Wo, Ci) x (Ci, Co) MXU matmuls into a
VMEM accumulator shared across the sequential grid — the Pallas analogue of
spconv's gather-GEMM-scatter. The final program applies bias + ReLU + the
occupancy mask (sparse-conv semantics).

Perf note (EXPERIMENTS.md §Perf): the first version walked output z-slices
(grid=(Do,)) and issued 27 tiny (Ho·Wo, Ci) dots per slice — 2.4 GFLOP/s on
the CPU backend. Restructuring to 3 programs x 9 volume-sized matmuls gives
XLA long contractions to fuse (5-10x wall-clock on the host and a far better
MXU utilization profile on a real TPU).

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (/opt/xla-example README).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv3d_kernel(x_ref, w_ref, b_ref, mask_ref, o_ref, *, stride, out_shape):
    """One kernel z-tap per program; accumulate into o_ref across the grid.

    x_ref:    (D+2, H+2, W+2, Ci) zero-padded input (whole array)
    w_ref:    (3, 3, 3, Ci, Co)
    b_ref:    (Co,)
    mask_ref: (Do, Ho, Wo, 1) output occupancy
    o_ref:    (Do, Ho, Wo, Co) accumulator across programs
    """
    do, ho, wo = out_shape
    sz, sy, sx = stride
    ci = x_ref.shape[-1]
    co = w_ref.shape[-1]
    kz = pl.program_id(0)

    # stage the z-shifted slab once: rows kz + sz*j for j < Do
    slab = pl.load(
        x_ref,
        (pl.dslice(kz, sz * (do - 1) + 1), slice(None), slice(None), slice(None)),
    )[::sz]  # (Do, H+2, W+2, Ci)

    acc = jnp.zeros((do * ho * wo, co), dtype=jnp.float32)
    for ky in range(3):
        for kx in range(3):
            patch = slab[
                :,
                ky : ky + sy * (ho - 1) + 1 : sy,
                kx : kx + sx * (wo - 1) + 1 : sx,
                :,
            ]  # (Do, Ho, Wo, Ci)
            acc += jnp.dot(
                patch.reshape(do * ho * wo, ci),
                w_ref[kz, ky, kx],
                preferred_element_type=jnp.float32,
            )
    acc = acc.reshape(do, ho, wo, co)

    @pl.when(kz == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(kz > 0)
    def _accum():
        o_ref[...] += acc

    @pl.when(kz == 2)
    def _finish():
        o_ref[...] = (
            jax.nn.relu(o_ref[...] + b_ref[...]) * mask_ref[...]
        )


@functools.partial(jax.jit, static_argnames=("stride",))
def conv3d_fused(x, w, b, mask, stride):
    """Drop-in for ref.conv3d_ref, as a Pallas kernel.

    x: (D, H, W, Ci); w: (3, 3, 3, Ci, Co); b: (Co,);
    mask: (Do, Ho, Wo, 1); stride: (sz, sy, sx). Returns (Do, Ho, Wo, Co).
    """
    d, h, wdim, ci = x.shape
    co = w.shape[-1]
    sz, sy, sx = stride
    do, ho, wo = d // sz, h // sy, wdim // sx

    xp = jnp.pad(x, ((1, 1), (1, 1), (1, 1), (0, 0)))
    kernel = functools.partial(
        _conv3d_kernel, stride=stride, out_shape=(do, ho, wo)
    )
    return pl.pallas_call(
        kernel,
        grid=(3,),
        in_specs=[
            # whole padded input visible to every program; the z-shifted
            # slab is a dynamic slice inside the kernel. On a real TPU this
            # would additionally block over y (DESIGN.md §Perf: VMEM-fit).
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0, 0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
            pl.BlockSpec((do, ho, wo, 1), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((do, ho, wo, co), lambda i: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((do, ho, wo, co), jnp.float32),
        interpret=True,
    )(xp, w, b, mask)
