"""Pallas fused 2D BEV convolution: conv3x3 (stride 1, SAME) + bias (+ReLU).

Grid walks row-tiles of the BEV map; each program loads its (TH+2)-row halo
slab and reduces the 9 taps as (TH·W, Ci) x (Ci, Co) matmuls. interpret=True
(CPU PJRT), see conv3d.py for the rationale.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8


def _conv2d_kernel(x_ref, w_ref, b_ref, o_ref, *, relu, tile, width):
    """x_ref: (H+2, W+2, Ci) padded whole map; o_ref: (TH, W, Co)."""
    ci = x_ref.shape[-1]
    co = w_ref.shape[-1]
    r0 = pl.program_id(0) * tile

    slab = pl.load(
        x_ref, (pl.dslice(r0, tile + 2), slice(None), slice(None))
    )  # (TH+2, W+2, Ci)
    acc = jnp.zeros((tile * width, co), dtype=jnp.float32)
    for ky in range(3):
        for kx in range(3):
            patch = slab[ky : ky + tile, kx : kx + width, :]
            acc += jnp.dot(
                patch.reshape(tile * width, ci),
                w_ref[ky, kx],
                preferred_element_type=jnp.float32,
            )
    out = acc + b_ref[...]
    if relu:
        out = jax.nn.relu(out)
    o_ref[...] = out.reshape(tile, width, co)


@functools.partial(jax.jit, static_argnames=("relu",))
def conv2d_fused(x, w, b, relu=True):
    """Drop-in for ref.conv2d_ref. x: (H, W, Ci) -> (H, W, Co)."""
    h, wdim, ci = x.shape
    co = w.shape[-1]
    tile = ROW_TILE if h % ROW_TILE == 0 else 1
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
    kernel = functools.partial(
        _conv2d_kernel, relu=relu, tile=tile, width=wdim
    )
    return pl.pallas_call(
        kernel,
        grid=(h // tile,),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, wdim, co), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, wdim, co), jnp.float32),
        interpret=True,
    )(xp, w, b)
