//! Quickstart: load the AOT artifacts, run one synthetic LiDAR frame at the
//! paper's recommended split (after VFE), and print the detections plus the
//! timing breakdown.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;

use splitpoint::config::SystemConfig;
use splitpoint::coordinator::Engine;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::Manifest;

fn main() -> Result<()> {
    // 1. load the model (HLO artifacts AOT'd by `make artifacts`)
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    println!(
        "loaded {} modules (grid {:?}, pallas={})",
        manifest.modules.len(),
        manifest.config.grid,
        manifest.use_pallas
    );

    // 2. build the engine with the paper's calibrated testbed profile
    let engine = Engine::new(&manifest, SystemConfig::paper())?;

    // 3. one synthetic KITTI-like frame
    let scene = SceneGenerator::with_seed(1).generate();
    println!(
        "scene: {} points, {} ground-truth objects",
        scene.cloud.len(),
        scene.boxes.len()
    );

    // 4. run at the paper's headline split: after VFE (voxelization)
    let sp = engine.graph().split_after("vfe")?;
    let result = engine.run_frame(&scene.cloud, sp)?;

    println!("\ntop detections:");
    for d in result.detections.iter().take(5) {
        println!(
            "  class={} score={:.2} box=({:.1}, {:.1}, {:.1}) {:.1}x{:.1}x{:.1} ry={:.2}",
            d.class, d.score, d.boxx[0], d.boxx[1], d.boxx[2], d.boxx[3], d.boxx[4],
            d.boxx[5], d.boxx[6]
        );
    }

    let t = &result.timing;
    println!("\ntiming (virtual clock, Jetson-calibrated):");
    println!("  inference time : {:>8.1} ms   (paper Fig 6)", t.inference_time.as_millis_f64());
    println!("  edge time      : {:>8.1} ms   (paper Fig 7)", t.edge_time.as_millis_f64());
    println!("  transfer size  : {:>8.2} MB   (paper Fig 8)", t.uplink_bytes as f64 / 1e6);
    println!("  transfer time  : {:>8.1} ms   (paper Fig 9)", t.uplink_time.as_millis_f64());
    println!("\nper module:");
    for (name, time, side) in &t.node_times {
        println!("  {name:<12} {:>8.1} ms on {side:?}", time.as_millis_f64());
    }
    Ok(())
}
