//! Adaptive split selection across changing network conditions.
//!
//! The paper picks split points offline (§III-B); this example shows the
//! coordinator choosing them automatically: for each link bandwidth the
//! analytic cost model prices every split and picks the argmin, exposing
//! the crossover the paper's Fig 6 implies (fast link → split early; slow
//! link → run on the edge).
//!
//! ```sh
//! make artifacts && cargo run --release --example split_sweep
//! ```

use anyhow::Result;

use splitpoint::config::SystemConfig;
use splitpoint::coordinator::adaptive::{choose_split, estimate_splits, Objective};
use splitpoint::coordinator::Engine;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::Manifest;

fn main() -> Result<()> {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let scene = SceneGenerator::with_seed(3).generate();

    println!("bandwidth sweep — chosen split per objective\n");
    println!(
        "{:<14} {:<18} {:<18}",
        "link MB/s", "min inference", "min edge load"
    );
    for mbps in [0.5, 2.0, 8.0, 32.0, 128.0, 512.0] {
        let mut cfg = SystemConfig::paper();
        cfg.link.bandwidth_bps = mbps * 1e6;
        let engine = Engine::new(&manifest, cfg)?;
        let fast = choose_split(&engine, &scene.cloud, Objective::InferenceTime)?;
        let light = choose_split(&engine, &scene.cloud, Objective::EdgeTime)?;
        println!(
            "{:<14} {:<18} {:<18}",
            mbps,
            format!("{} ({:.0} ms)", fast.label, fast.inference_time.as_millis_f64()),
            format!("{} ({:.0} ms)", light.label, light.edge_time.as_millis_f64()),
        );
    }

    // full table at the paper's calibrated link
    let engine = Engine::new(&manifest, SystemConfig::paper())?;
    println!("\nfull cost table at the paper link:\n");
    println!(
        "{:<18} {:>10} {:>12} {:>14}",
        "split", "wire MB", "edge ms", "inference ms"
    );
    for e in estimate_splits(&engine, &scene.cloud)? {
        println!(
            "{:<18} {:>10.2} {:>12.1} {:>14.1}",
            e.label,
            e.uplink_bytes as f64 / 1e6,
            e.edge_time.as_millis_f64(),
            e.inference_time.as_millis_f64()
        );
    }
    Ok(())
}
