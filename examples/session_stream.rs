//! The `SplitSession` facade end-to-end: one builder assembles the frame
//! source, transport, and split policy that `main.rs` used to hand-wire
//! per subcommand.
//!
//! Streams synthetic scenes through the depth-2 staged pipeline under an
//! *adaptive* split policy: every few frames the session re-costs every
//! split from the live EWMA bandwidth estimate (fed by the transport's own
//! observed transfers) and switches — with hysteresis — when a different
//! split wins. Swap `.synthetic(...)` for `.source_spec(Some("kitti:<dir>"),
//! ...)` to stream real KITTI `.bin` scans instead.
//!
//! ```sh
//! make artifacts && cargo run --release --example session_stream
//! ```

use anyhow::Result;

use splitpoint::coordinator::adaptive::Objective;
use splitpoint::coordinator::session::{Adaptive, SplitSession};

fn main() -> Result<()> {
    let mut session = SplitSession::builder()
        .artifacts("artifacts")
        .synthetic(7, 24)
        .policy(Box::new(Adaptive::new(Objective::InferenceTime).every(6)))
        .pipeline_depth(2)
        .build()?;

    println!("{}\n", session.describe());

    let report = session.run_with(|f| {
        println!(
            "frame {:>2} [{}]: {:>5} pts, {:>2} dets | inference {:>7.1} ms, uplink {:>6.2} MB",
            f.seq,
            f.split_label,
            f.points,
            f.output.detections.len(),
            f.output.inference_time.as_millis_f64(),
            f.output.uplink_bytes as f64 / 1e6,
        );
    })?;

    println!("\n{}", report.summary());
    if let Some(md) = &report.transport_report {
        println!("\n{md}");
    }
    Ok(())
}
