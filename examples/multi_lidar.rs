//! Multi-LiDAR serving (paper §VI future work: "integrated data from
//! multiple LiDARs"): S sensor threads stream frames into the batcher; a
//! worker pool drains batches through the engine at the configured split,
//! and the run reports end-to-end latency and aggregate throughput.
//!
//! This is the end-to-end serving driver recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example multi_lidar [sensors] [frames-per-sensor]
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use splitpoint::config::SystemConfig;
use splitpoint::coordinator::batcher::{BatchPolicy, Batcher};
use splitpoint::coordinator::Engine;
use splitpoint::metrics::Recorder;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::pointcloud::Frame;
use splitpoint::Manifest;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let sensors: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let frames_per_sensor: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let workers: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(2);

    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let engine = Arc::new(Engine::new(&manifest, SystemConfig::paper())?);
    let sp = engine.graph().split_after("vfe")?;

    let batcher = Arc::new(Batcher::new(BatchPolicy {
        max_frames: 4,
        max_wait: Duration::from_millis(30),
    }));

    println!(
        "{sensors} sensors x {frames_per_sensor} frames, {workers} workers, split after VFE"
    );

    // ---- sensor threads: 10 Hz-ish LiDAR emission
    let mut sensor_threads = Vec::new();
    for sensor_id in 0..sensors as u32 {
        let batcher = batcher.clone();
        sensor_threads.push(std::thread::spawn(move || {
            let mut gen = SceneGenerator::with_seed(1000 + sensor_id as u64);
            for seq in 0..frames_per_sensor as u64 {
                batcher.push(Frame {
                    sensor_id,
                    seq,
                    cloud: gen.generate().cloud,
                });
                std::thread::sleep(Duration::from_millis(25));
            }
        }));
    }

    // ---- worker pool drains batches through the engine
    let recorder = Arc::new(Mutex::new(Recorder::new()));
    let processed = Arc::new(AtomicUsize::new(0));
    let t_start = Instant::now();
    let mut worker_threads = Vec::new();
    for _ in 0..workers {
        let batcher = batcher.clone();
        let engine = engine.clone();
        let recorder = recorder.clone();
        let processed = processed.clone();
        worker_threads.push(std::thread::spawn(move || -> Result<()> {
            while let Some(batch) = batcher.next_batch() {
                for frame in batch {
                    let t0 = Instant::now();
                    let r = engine.run_frame(&frame.cloud, sp)?;
                    let wall = t0.elapsed().as_secs_f64() * 1e3;
                    let mut rec = recorder.lock().unwrap();
                    rec.record("wall_ms_per_frame", wall);
                    rec.record(
                        "virtual_inference_ms",
                        r.timing.inference_time.as_millis_f64(),
                    );
                    rec.record("detections", r.detections.len() as f64);
                    processed.fetch_add(1, Ordering::Relaxed);
                }
            }
            Ok(())
        }));
    }

    for t in sensor_threads {
        t.join().unwrap();
    }
    batcher.close();
    for t in worker_threads {
        t.join().unwrap()?;
    }

    let wall = t_start.elapsed().as_secs_f64();
    let total = processed.load(Ordering::Relaxed);
    assert_eq!(total, sensors * frames_per_sensor, "lost frames!");

    println!("\n{}", recorder.lock().unwrap().to_markdown("multi-LiDAR serving"));
    println!(
        "processed {total} frames in {wall:.1} s -> throughput {:.2} frames/s",
        total as f64 / wall
    );
    Ok(())
}
