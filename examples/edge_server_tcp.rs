//! Two-process split computing over a real TCP socket, in one binary:
//! spawns the edge-server (paper Fig 1's roadside server), then streams
//! frames from an in-process edge client through the paper's three split
//! patterns and reports wall-clock timings.
//!
//! For a true two-machine run use the CLI instead:
//! `splitpoint serve-server` on one host, `splitpoint serve-edge` on the
//! other.
//!
//! ```sh
//! make artifacts && cargo run --release --example edge_server_tcp
//! ```

use std::sync::Arc;

use anyhow::Result;

use splitpoint::config::SystemConfig;
use splitpoint::coordinator::remote::{EdgeClient, Server};
use splitpoint::coordinator::Engine;
use splitpoint::metrics::Recorder;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::Manifest;

const FRAMES: usize = 5;

fn main() -> Result<()> {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let engine = Arc::new(Engine::new(&manifest, SystemConfig::paper())?);

    // edge-server process (in-proc thread, real socket)
    let server = Server::spawn("127.0.0.1:0", engine.clone())?;
    println!("edge-server listening on {}", server.addr());

    let mut recorder = Recorder::new();
    let mut client = EdgeClient::connect(server.addr(), engine.clone())?;

    for split in ["vfe", "conv1", "conv2"] {
        let sp = engine.graph().split_after(split)?;
        let mut gen = SceneGenerator::with_seed(7);
        for _ in 0..FRAMES {
            let scene = gen.generate();
            let (dets, t) = client.run_frame(&scene.cloud, sp)?;
            recorder.record(&format!("{split}/edge_ms"), t.edge_compute.as_millis_f64());
            recorder.record(&format!("{split}/rtt_ms"), t.round_trip.as_millis_f64());
            recorder.record(
                &format!("{split}/server_ms"),
                t.server_compute.as_millis_f64(),
            );
            recorder.record(
                &format!("{split}/uplink_mb"),
                t.uplink_bytes as f64 / 1e6,
            );
            recorder.record(
                &format!("{split}/total_ms"),
                t.inference_time.as_millis_f64(),
            );
            assert!(!dets.is_empty());
        }
        println!("split after {split}: {FRAMES} frames done");
    }

    client.shutdown()?;
    server.shutdown()?;

    println!(
        "\n{}",
        recorder.to_markdown("real-TCP wall-clock timings (host speed, no device scaling)")
    );
    println!(
        "note: these numbers demonstrate the mechanism on this host; the\n\
         paper-comparable figures come from the calibrated virtual clock\n\
         (`splitpoint sweep`, cargo bench)."
    );
    Ok(())
}
